"""Paper-native scenario (§2.1 workflow + disconnected operation):

1. a scientist's laptop (home) holds source + input data;
2. the pod site mounts the namespace, prefetches the source tree, caches
   the big input, and starts producing results with write-behind;
3. the laptop drops off the network MID-RUN — the job keeps going from
   cache, queueing its outputs in the WAL;
4. the laptop returns; the queue drains; a callback invalidation proves
   coherency after a home-side edit;
5. raw output in a *localized directory* never crosses the WAN.

    PYTHONPATH=src python examples/disconnected_ops.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (
    DisconnectedError, Fabric, FabricSpec, MountSpec, SiteSpec,
)


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        fabric = Fabric(FabricSpec(sites=(
            SiteSpec("laptop", root=td + "/laptop"),
            SiteSpec("pod", root=td + "/pod"),
        )))
        net = fabric.network
        s = fabric.login("ewalker", home="laptop", site="pod",
                         mounts=[MountSpec("home/",
                                           ("home/scratch/raw/",))])
        tok = s.token

        # laptop: project files
        for i in range(20):
            s.server.store.put(tok, f"home/src/mod{i}.c",
                               b"code\n" * 500)
        s.server.store.put(tok, "home/input/data.bin", b"\x01" * 50_000_000)

        # pod: cd (parallel prefetch) + cache the big input
        n = s.client.chdir("home/src")
        print(f"prefetched {n} small sources; WAN clock {net.clock:.2f}s")
        with s.client.open("home/input/data.bin") as f:
            data = f.read()
        print(f"cached {len(data):,}B input; WAN clock {net.clock:.2f}s")

        # laptop leaves the network (the paper's core assumption!)
        net.partition("pod", "laptop")
        print("-- laptop disconnected --")
        with s.client.open("home/input/data.bin") as f:
            assert f.read() == data          # still served, from cache
        for step in range(3):
            with s.client.open(f"home/results/step{step}.out", "w") as f:
                f.write(b"result" * 1000)
            with s.client.open("home/scratch/raw/dump.bin", "w") as f:
                f.write(b"\x00" * 10_000_000)    # localized: stays on pod
        queued = len(s.client.oplog.pending())
        print(f"queued {queued} ops while offline "
              f"(raw dump localized, not queued)")

        # laptop comes back; the WAL drains in order
        net.heal("pod", "laptop")
        drained = s.client.sync()
        print(f"-- reconnected: drained {drained} ops --")
        got, _ = s.server.store.get(tok, "home/results/step2.out")
        assert got == b"result" * 1000
        try:
            s.server.store.get(tok, "home/scratch/raw/dump.bin")
            raise AssertionError("localized file leaked to home!")
        except FileNotFoundError:
            print("localized raw output never left the pod  ✓")

        # coherency: home-side edit invalidates the pod's cache
        stale = s.client.reconnect()
        s.server.store.put(tok, "home/src/mod0.c", b"edited\n")
        s.client.pump_callbacks()
        with s.client.open("home/src/mod0.c") as f:
            assert f.read() == b"edited\n"
        print("callback invalidation + refetch  ✓")
        print(f"final WAN clock {net.clock:.2f}s, "
              f"bytes shipped {net.bytes_sent:,}")


if __name__ == "__main__":
    main()
