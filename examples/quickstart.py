"""Quickstart: the whole system in ~60 lines.

Mount a home namespace over the simulated WAN, materialize a dataset,
train a tiny Qwen3-family model with write-behind checkpointing, then
serve a few requests from the trained weights.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import Fabric, FabricSpec, MountSpec, SiteSpec
from repro.config import RunConfig, ShapeConfig, OptimConfig
from repro.configs import get_tiny_config
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticCorpus, DataPipeline
from repro.serve.engine import ServeEngine, Request
from repro.train import Trainer


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        # 1. declare the topology, then USSH login: personal file server
        #    at "home", pod site mounts it (scratch/ stays pod-local)
        fabric = Fabric(FabricSpec(sites=(
            SiteSpec("home", root=td + "/home"),
            SiteSpec("site", root=td + "/site"),
        )))
        net = fabric.network
        s = fabric.login("scientist",
                         mounts=[MountSpec("home/", ("home/scratch/",))])

        # 2. input data lives in the home space; the pod reads it through
        #    the whole-object cache + prefetcher
        cfg = get_tiny_config("qwen3-4b")
        SyntheticCorpus(s.client, "home/data", seed=0,
                        vocab=cfg.vocab_size,
                        shard_tokens=8192).materialize(2)
        pipe = DataPipeline(s.client, "home/data", cfg, batch=4, seq=32,
                            n_shards=2)

        # 3. train with write-behind checkpoints (WAL -> striped -> home)
        run = RunConfig(model=cfg,
                        shape=ShapeConfig("quick", "train", 32, 4),
                        optim=OptimConfig(lr=1e-3, warmup_steps=5,
                                          total_steps=100))
        ckpt = CheckpointManager(s.client, "home/ckpt")
        trainer = Trainer(run, pipe, ckpt, ckpt_every=10)
        result = trainer.train(20)
        print(f"loss: {result.losses[0]:.3f} -> {result.losses[-1]:.3f}  "
              f"checkpoints at {result.checkpoints}")
        print(f"virtual WAN time: {net.clock:.2f}s, "
              f"bytes shipped: {net.bytes_sent:,}")

        # 4. serve from the trained weights (continuous batching)
        engine = ServeEngine(cfg, trainer.params, slots=2, max_len=64)
        for rid, prompt in enumerate(([1, 2, 3], [9, 8, 7, 6])):
            engine.add_request(Request(rid=rid, prompt=prompt,
                                       max_new_tokens=8))
        engine.run_until_done()
        for rid in (0, 1):
            print(f"request {rid}: {engine.requests[rid].output}")


if __name__ == "__main__":
    main()
