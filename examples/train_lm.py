"""End-to-end training driver: a ~100M-param Qwen3-family LM trained for a
few hundred steps through the full stack (XUFS data fabric, write-behind
checkpointing, fault injection mid-run, crash recovery).

    PYTHONPATH=src python examples/train_lm.py --preset full    # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm.py --preset smoke   # CI-sized

The full preset is sized for a real accelerator; on this CPU-only
container use --preset smoke (identical code path, smaller widths).
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.config import (
    ModelConfig, RunConfig, ShapeConfig, OptimConfig, DENSE,
)
from repro.core import Fabric, FabricSpec, MountSpec, SiteSpec
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticCorpus, DataPipeline
from repro.train import Trainer, FaultMonitor, FaultEvent

PRESETS = {
    # ~100M params: 12L x 640d x 10H, vocab 32k
    "full": dict(layers=12, d_model=640, heads=10, kv_heads=5, d_ff=2560,
                 vocab=32768, seq=1024, batch=8, steps=300, micro=2),
    "smoke": dict(layers=2, d_model=128, heads=4, kv_heads=2, d_ff=512,
                  vocab=2048, seq=64, batch=4, steps=30, micro=1),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="smoke")
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]

    cfg = ModelConfig(
        name=f"lm-{args.preset}", family=DENSE, num_layers=p["layers"],
        d_model=p["d_model"], num_heads=p["heads"],
        num_kv_heads=p["kv_heads"], head_dim=p["d_model"] // p["heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab"], qk_norm=True,
        remat="full")
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    with tempfile.TemporaryDirectory() as td:
        fabric = Fabric(FabricSpec(sites=(
            SiteSpec("home", root=td + "/home"),
            SiteSpec("site", root=td + "/site"),
        )))
        net = fabric.network
        s = fabric.login("trainer",
                         mounts=[MountSpec("home/", ("home/scratch/",))])
        SyntheticCorpus(s.client, "home/data", seed=0,
                        vocab=cfg.vocab_size,
                        shard_tokens=max(p["seq"] * p["batch"] * 4, 8192)
                        ).materialize(4)
        pipe = DataPipeline(s.client, "home/data", cfg, batch=p["batch"],
                            seq=p["seq"], n_shards=4)
        run = RunConfig(model=cfg,
                        shape=ShapeConfig("train", "train", p["seq"],
                                          p["batch"]),
                        optim=OptimConfig(lr=3e-4, warmup_steps=20,
                                          total_steps=steps),
                        microbatches=p["micro"])
        ckpt = CheckpointManager(s.client, "home/ckpt")
        # inject a node failure a third of the way through
        monitor = FaultMonitor(n_workers=8, schedule=[
            FaultEvent(step=max(steps // 3, 2), worker=3, kind="crash")])
        trainer = Trainer(run, pipe, ckpt, monitor=monitor,
                          ckpt_every=max(steps // 10, 5))
        res = trainer.train(steps)
        print(f"steps={res.steps_run} restarts={res.restarts} "
              f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}")
        print(f"WAN clock {net.clock:.1f}s; checkpoints {res.checkpoints}")

        # cold-restart proof: a fresh trainer restores the newest manifest
        t2 = Trainer(run, pipe, ckpt)
        t2.initialize()
        assert t2.restore_latest(), "no restorable checkpoint!"
        print(f"cold restore OK at step {t2.step}")


if __name__ == "__main__":
    main()
