"""Batched serving example: weights arrive through the XUFS fabric
(striped restore + small-tensor prefetch), then a continuous-batching
engine serves a stream of requests.

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.core import Fabric, FabricSpec, SiteSpec
from repro.checkpoint import CheckpointManager
from repro.configs import get_tiny_config
from repro.models import init_params
from repro.serve.engine import ServeEngine, Request


def main() -> None:
    with tempfile.TemporaryDirectory() as td:
        fabric = Fabric(FabricSpec(sites=(
            SiteSpec("home", root=td + "/home"),
            SiteSpec("site", root=td + "/site"),
        )))
        net = fabric.network
        s = fabric.login("server")
        cfg = get_tiny_config("qwen3-8b").replace(param_dtype="bfloat16")

        # publisher side: push weights into the home store
        params = init_params(cfg, jax.random.PRNGKey(0))
        mgr = CheckpointManager(s.client, "home/models/qwen3-tiny")
        mgr.save(0, {"params": params})
        s.client.sync()
        print(f"published weights; WAN bytes {net.bytes_sent:,}")

        # serving side: striped restore through the cache
        clock0 = net.clock
        restored, manifest = mgr.restore({"params": params})
        print(f"weights restored in {net.clock - clock0:.2f}s WAN time "
              f"(step {manifest['step']})")

        engine = ServeEngine(cfg, restored["params"], slots=4, max_len=128)
        requests = [
            Request(rid=i, prompt=list(range(1 + i, 6 + i)),
                    max_new_tokens=12)
            for i in range(10)
        ]
        for r in requests:
            engine.add_request(r)
        ticks = 0
        while any(not r.done for r in requests):
            engine.step()
            ticks += 1
        print(f"served {len(requests)} requests in {ticks} engine ticks, "
              f"{engine.tokens_generated} tokens generated")
        for r in requests[:3]:
            print(f"  rid={r.rid} output={r.output}")


if __name__ == "__main__":
    main()
