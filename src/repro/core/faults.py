"""Deterministic fault-injection harness for the simulated WAN.

Hand-rolled chaos — ``network.partition(...)`` / ``heal(...)`` calls
threaded through test and benchmark choreography — couples the fault
schedule to the code path that happens to run next.  This module makes
the schedule *declarative*: a :class:`FaultPlan` is a tuple of events
pinned to the virtual clock (:class:`PartitionEvent`,
:class:`HealEvent`, :class:`FlapEvent`, :class:`CrashEvent`), and a
:class:`FaultInjector` armed on a :class:`~repro.core.transport.Network`
fires them lazily: every partition-sensitive operation (and
``Network.advance``) first releases all events whose time the clock has
reached.  Outage windows are anchored at the *event* time, not the pump
time, so auto-heal deadlines never depend on when a check happened to
run — same plan + same workload => bit-identical ``Network.trace``.

``FaultPlan.chaos(...)`` generates a seeded random plan (partitions of
bounded duration over a declared link set, optional site crashes) for
property tests: same seed => same plan => same trace.

An unarmed network never touches this module — the no-fault fast path
stays bit-identical to a build without it.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

__all__ = [
    "PartitionEvent", "HealEvent", "FlapEvent", "CrashEvent",
    "FaultPlan", "FaultInjector",
]

_INF = float("inf")


@dataclass(frozen=True)
class PartitionEvent:
    """Cut link ``a <-> b`` at ``at_s`` for ``duration_s`` virtual
    seconds (default: until an explicit :class:`HealEvent`)."""
    at_s: float
    a: str
    b: str
    duration_s: float = _INF

    def __post_init__(self) -> None:
        if self.at_s < 0.0:
            raise ValueError(f"PartitionEvent.at_s must be >= 0, got {self.at_s}")
        if self.duration_s <= 0.0:
            raise ValueError(
                f"PartitionEvent.duration_s must be > 0, got {self.duration_s}")


@dataclass(frozen=True)
class HealEvent:
    """Heal link ``a <-> b`` at ``at_s`` (no-op if not partitioned)."""
    at_s: float
    a: str
    b: str

    def __post_init__(self) -> None:
        if self.at_s < 0.0:
            raise ValueError(f"HealEvent.at_s must be >= 0, got {self.at_s}")


@dataclass(frozen=True)
class FlapEvent:
    """A flapping link: ``count`` outages of ``down_s`` each, the k-th
    starting at ``at_s + k * period_s``.  Expands to ``count``
    anchored :class:`PartitionEvent` windows."""
    at_s: float
    a: str
    b: str
    down_s: float
    period_s: float
    count: int = 1

    def __post_init__(self) -> None:
        if self.at_s < 0.0:
            raise ValueError(f"FlapEvent.at_s must be >= 0, got {self.at_s}")
        if self.down_s <= 0.0:
            raise ValueError(f"FlapEvent.down_s must be > 0, got {self.down_s}")
        if self.period_s <= 0.0:
            raise ValueError(
                f"FlapEvent.period_s must be > 0, got {self.period_s}")
        if self.count < 1:
            raise ValueError(f"FlapEvent.count must be >= 1, got {self.count}")


@dataclass(frozen=True)
class CrashEvent:
    """Crash the user file server(s) at ``site`` at ``at_s`` (volatile
    session state — auth tokens, subscriptions — is lost; the client
    recovers via ``reconnect()``/``remount()``)."""
    at_s: float
    site: str

    def __post_init__(self) -> None:
        if self.at_s < 0.0:
            raise ValueError(f"CrashEvent.at_s must be >= 0, got {self.at_s}")


_EVENT_TYPES = (PartitionEvent, HealEvent, FlapEvent, CrashEvent)


@dataclass(frozen=True)
class FaultPlan:
    """A declarative, virtual-clock fault schedule.

    ``events`` may arrive in any order; expansion sorts actions by
    ``(time, declaration index)`` so ties resolve deterministically in
    declaration order.
    """
    events: Tuple = ()

    def __post_init__(self) -> None:
        evs = tuple(self.events)
        for ev in evs:
            if not isinstance(ev, _EVENT_TYPES):
                raise TypeError(
                    f"FaultPlan events must be Partition/Heal/Flap/Crash "
                    f"events, got {type(ev).__name__}")
        object.__setattr__(self, "events", evs)

    def actions(self) -> List[Tuple[float, int, str, tuple]]:
        """Expand to a time-sorted action list
        ``(at_s, decl_index, kind, args)`` — flaps become their
        individual outage windows."""
        acts: List[Tuple[float, int, str, tuple]] = []
        for i, ev in enumerate(self.events):
            if isinstance(ev, PartitionEvent):
                acts.append((ev.at_s, i, "partition",
                             (ev.a, ev.b, ev.duration_s)))
            elif isinstance(ev, HealEvent):
                acts.append((ev.at_s, i, "heal", (ev.a, ev.b)))
            elif isinstance(ev, CrashEvent):
                acts.append((ev.at_s, i, "crash", (ev.site,)))
            else:  # FlapEvent
                for k in range(ev.count):
                    acts.append((ev.at_s + k * ev.period_s, i, "partition",
                                 (ev.a, ev.b, ev.down_s)))
        acts.sort(key=lambda t: (t[0], t[1]))
        return acts

    @classmethod
    def chaos(cls, pairs: Sequence[Tuple[str, str]], *, seed: int,
              horizon_s: float, events: int = 8, start_s: float = 0.0,
              min_down_s: float = 0.5, max_down_s: float = 5.0,
              crash_sites: Sequence[str] = ()) -> "FaultPlan":
        """Seeded random chaos: ``events`` finite outages spread over
        ``[start_s, start_s + horizon_s)`` across ``pairs``, plus an
        optional coin-flip crash per site in ``crash_sites``.  Pure
        function of its arguments — same seed => same plan."""
        if not pairs:
            raise ValueError("chaos() needs at least one link pair")
        if horizon_s <= 0.0:
            raise ValueError(f"horizon_s must be > 0, got {horizon_s}")
        if not 0.0 < min_down_s <= max_down_s:
            raise ValueError("need 0 < min_down_s <= max_down_s")
        rng = random.Random(seed)
        evs: List = []
        for _ in range(max(int(events), 0)):
            a, b = pairs[rng.randrange(len(pairs))]
            at = start_s + rng.random() * horizon_s
            down = min_down_s + rng.random() * (max_down_s - min_down_s)
            evs.append(PartitionEvent(at_s=round(at, 6), a=a, b=b,
                                      duration_s=round(down, 6)))
        for site in crash_sites:
            if rng.random() < 0.5:
                at = start_s + rng.random() * horizon_s
                evs.append(CrashEvent(at_s=round(at, 6), site=site))
        return cls(events=tuple(evs))


@dataclass
class FaultInjector:
    """Replays a :class:`FaultPlan` onto a network as the virtual clock
    passes each event.  Armed via ``Network.arm_faults`` (and, when a
    maintenance scheduler runs, mirrored on ``scheduler.faults`` so
    ``run_until`` walks the clock to fault times even with no task
    due).  ``crash_fn(site) -> int`` is supplied by the fabric; without
    one, :class:`CrashEvent` is a recorded no-op."""
    network: object
    plan: FaultPlan
    crash_fn: Optional[Callable[[str], int]] = None
    fired: int = 0
    crashes: int = 0

    def __post_init__(self) -> None:
        self._actions = self.plan.actions()
        self._idx = 0

    def next_at(self) -> Optional[float]:
        """Virtual time of the next unfired event (None when spent)."""
        if self._idx >= len(self._actions):
            return None
        return self._actions[self._idx][0]

    def done(self) -> bool:
        return self._idx >= len(self._actions)

    def advance_to(self, now: float) -> int:
        """Fire every event with ``at_s <= now``, in schedule order.
        Partition windows anchor at their event time (``start=at_s``),
        so a window the clock has fully passed is skipped rather than
        stretched.  Returns the number of events fired."""
        acts = self._actions
        n = 0
        while self._idx < len(acts) and acts[self._idx][0] <= now:
            at, _decl, kind, a = acts[self._idx]
            self._idx += 1
            if kind == "partition":
                self.network.partition(a[0], a[1], a[2], start=at)
            elif kind == "heal":
                self.network.heal(a[0], a[1])
            else:  # crash
                if self.crash_fn is not None:
                    self.crashes += int(self.crash_fn(a[0]))
            n += 1
        self.fired += n
        return n
