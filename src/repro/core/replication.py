"""Multi-site replica fabric: catalog, nearest-replica reads, fan-out.

XUFS as published assumes a single authoritative home store; this module
adds SCISPACE-style per-site read replicas on top of the same
``Network``/``HomeStore`` fabric, following the GridFTP replica-management
recipe (replica catalog + striped transfer):

  * :class:`ReplicaCatalog` maps ``path -> {endpoint: version}`` plus the
    home's latest version per path.  A holder is *fresh* iff its version is
    at least the home version the catalog has seen — callback notifications
    from the home store keep the catalog current, so a stale replica drops
    out of the read path the moment home changes (the replica-side
    equivalent of ``cache.INVALID``).
  * :class:`ReplicaSet` places the replicas, routes reads to the fresh
    holder with the lowest *estimated completion* — static latency plus
    channel queue depth plus NIC backlog, so a hammered replica sheds
    reads to the next-nearest fresh holder (home is always the terminal
    fallback, whatever its queue) —
    fans writes out home-first-then-replicas under a W-of-N ack policy
    (``write_quorum``; see ``docs/consistency.md``) so a lagging or
    partitioned replica never blocks the client below W — and a
    partitioned *home* no longer stalls writes when W > 1 — and repairs
    divergence via ``resync()`` (anti-entropy over the home version
    vector).

The catalog is metadata colocated with the home service and mirrored to
clients over the callback channel; lookups are therefore modeled as free —
only data movement and per-operation RPCs charge the virtual clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple, Union

from repro.core.bulk import BulkSpec
from repro.core.oplog import vts_merge
from repro.core.store import HomeStore, ObjectStat
from repro.core.striping import StripedTransfer, TransferGroup
from repro.core.transport import (
    AuthError, DisconnectedError, Network, Transfer, respond,
)


class WriteLeaseContended(DisconnectedError):
    """Another writer holds the per-path write lease on a common
    replica.  Subclasses :class:`DisconnectedError` on purpose: the
    flusher treats it like a WAN fault — the drain stops, the op stays
    queued, and the next pump retries (by which time the holder has
    reconciled or its short TTL lapsed)."""

#: A read source the client can try: (endpoint name, store, auth token).
ReadSource = Tuple[str, HomeStore, str]

#: Write-ack policy: an explicit W, or "majority" / "all" of the N
#: endpoints (home + replicas).  W=1 degenerates to the legacy policy —
#: the home apply alone is the ack and replica fan-out stays best-effort.
WritePolicy = Union[int, str]

#: Nominal payload the router prices a candidate with when the caller
#: does not know the object size yet (a cold ``open`` learns the size
#: only after choosing a source).  Large enough that NIC backlog and
#: queue depth dominate latency on a loaded endpoint, small enough that
#: an idle network still ranks by pure latency.
ROUTE_PROBE_BYTES = 1024 * 1024


#: Shared empty result for directories the catalog knows nothing under.
_NO_PATHS: Set[str] = frozenset()   # type: ignore[assignment]


@dataclass(frozen=True)
class WriteLeaseSpec:
    """Per-path write leases over the replica set for quorum writes
    around a dead home.

    Before assigning a client-side version, the flusher must hold a
    short-TTL lease on **every** replica it can reach (owner
    ``write:<user>``, the PR 6 owner-prefix pattern) — so two sessions
    writing one path during the same outage serialize whenever any
    common replica is reachable: the second writer's drain defers
    (:class:`WriteLeaseContended`) and retries after the first
    reconciles or the TTL lapses, by which point it observes the first
    write's vector timestamp and lands causally *after* it instead of
    concurrently.  Under a full partition (no replica reachable) writes
    fall back to vector-timestamp tagging and conflict detection at
    reconcile.  Unset (``ReplicaPolicy.write_lease=None``) keeps the
    write path lease-free and every trace bit-identical.
    """

    ttl_s: float = 10.0

    def __post_init__(self) -> None:
        if self.ttl_s <= 0:
            raise ValueError(
                f"WriteLeaseSpec.ttl_s must be > 0: {self.ttl_s}")


@dataclass(frozen=True)
class EvictionSpec:
    """Capacity-aware placement/eviction policy for one home space's
    replicas (GridFTP replica-management line: placement under finite
    replica storage is *the* wide-area problem).

    ``capacity`` bounds each replica's resident bytes; the scheduled
    ``evict:`` task scans every ``scan_period_s`` and, once resident
    bytes cross ``high_watermark * capacity``, evicts candidates ranked
    by ``policy`` — ``"lru"`` (coldest last-touch first) or
    ``"fill_cost"`` (fewest fills served first, LRU tie-break, i.e.
    least projected refill traffic) — down to
    ``low_watermark * capacity``.  A capacity-bounded replica also stops
    mirroring the home space: resync refreshes only what is already
    resident, and placement happens on demand via read repair
    (``docs/maintenance.md``).  Unset (``ReplicaPolicy.eviction=None``)
    keeps replicas unbounded and every trace bit-identical.
    """

    capacity: int
    high_watermark: float = 0.9
    low_watermark: float = 0.6
    policy: str = "lru"
    scan_period_s: float = 10.0

    def __post_init__(self) -> None:
        if self.capacity <= 0:
            raise ValueError(
                f"EvictionSpec.capacity must be > 0 bytes: {self.capacity}")
        if not (0.0 < self.low_watermark <= self.high_watermark <= 1.0):
            raise ValueError(
                "watermarks must satisfy 0 < low <= high <= 1: "
                f"low={self.low_watermark}, high={self.high_watermark}")
        if self.policy not in ("lru", "fill_cost"):
            raise ValueError(
                f"eviction policy must be 'lru' or 'fill_cost': "
                f"{self.policy!r}")
        if self.scan_period_s <= 0:
            raise ValueError(
                f"scan_period_s must be > 0: {self.scan_period_s}")

    @property
    def high_bytes(self) -> int:
        """Resident bytes beyond which a scan starts evicting."""
        return int(self.high_watermark * self.capacity)

    @property
    def low_bytes(self) -> int:
        """The scan's target: evict until resident bytes <= this."""
        return int(self.low_watermark * self.capacity)


class ReplicaCatalog:
    """``path -> {endpoint: version}`` plus the home version per path.

    ``quorum_versions`` additionally tracks versions that reached a write
    quorum while home was partitioned: freshness is judged against the
    newest version known on *either* channel, so a read may be served
    fresh from an acked replica even when home has never seen the write.
    """

    def __init__(self) -> None:
        self.home_versions: Dict[str, int] = {}
        self.quorum_versions: Dict[str, int] = {}
        self._holders: Dict[str, Dict[str, int]] = {}
        #: True once the FULL home version vector has been learned
        #: (resync/reattach).  Until then the catalog only knows changes
        #: it witnessed, so it cannot prove a listing complete — objects
        #: that predate the subscription may exist at home unseen.
        self.vector_learned = False
        #: Bumped on every state change — memoized routes key on it.
        self.gen = 0
        # per-directory index: "a/b/" -> every known path under it (any
        # depth), so route_meta never scans the whole catalog per call.
        # Paths are never unindexed: a deletion keeps its (negative-
        # version) catalog entry, and consumers filter by freshness floor.
        self._by_dir: Dict[str, Set[str]] = {}
        self._indexed: Set[str] = set()

    def _index(self, path: str) -> None:
        if path in self._indexed:
            return
        self._indexed.add(path)
        parts = path.split("/")
        for i in range(1, len(parts)):
            d = "/".join(parts[:i]) + "/"
            self._by_dir.setdefault(d, set()).add(path)

    def paths_under(self, dir_prefix: str) -> Set[str]:
        """Known paths under the directory (``dir_prefix`` ends with
        "/"); directory-boundary match, same as the old linear scan.

        Returns a live READ-ONLY view of the index (an empty frozenset
        for unknown directories) — callers must copy before mutating,
        or they corrupt the index behind the catalog's back."""
        return self._by_dir.get(dir_prefix, _NO_PATHS)

    # ---- home side -------------------------------------------------------
    def note_home(self, path: str, version: int) -> None:
        changed = self.home_versions.get(path) != version
        self.home_versions[path] = version
        self._index(path)
        qv = self.quorum_versions.get(path)
        if qv is not None and version >= qv:
            # home caught up with the quorum write: single authority again
            del self.quorum_versions[path]
            changed = True
        if changed:
            self.gen += 1

    def home_version(self, path: str) -> Optional[int]:
        return self.home_versions.get(path)

    # ---- quorum side -----------------------------------------------------
    def note_quorum(self, path: str, version: int) -> None:
        """A W-of-N quorum acked ``version`` with home unreachable."""
        if version > self.quorum_versions.get(path, 0):
            self.quorum_versions[path] = version
            self._index(path)
            self.gen += 1

    def forget_quorum(self, path: str) -> None:
        """Drop the quorum-side floor (the path was deleted or home
        re-learned it through another channel)."""
        if self.quorum_versions.pop(path, None) is not None:
            self.gen += 1

    def freshness_floor(self, path: str) -> Optional[int]:
        """Newest version known home-side or via a quorum ack."""
        hv = self.home_versions.get(path)
        qv = self.quorum_versions.get(path)
        if qv is not None and (hv is None or qv > hv):
            return qv
        return hv

    # ---- holders ---------------------------------------------------------
    def record(self, path: str, endpoint: str, version: int) -> None:
        holders = self._holders.setdefault(path, {})
        if holders.get(endpoint) != version:
            holders[endpoint] = version
            self.gen += 1

    def drop(self, path: str, endpoint: Optional[str] = None) -> None:
        if endpoint is None:
            if self._holders.pop(path, None) is not None:
                self.gen += 1
            return
        holders = self._holders.get(path)
        if holders is not None and holders.pop(endpoint, None) is not None:
            self.gen += 1

    def version_at(self, path: str, endpoint: str) -> Optional[int]:
        return self._holders.get(path, {}).get(endpoint)

    def paths_at(self, endpoint: str) -> List[str]:
        return [p for p, h in self._holders.items() if endpoint in h]

    def fresh_holders(self, path: str) -> List[str]:
        """Endpoints holding a version at least as new as the floor.

        The floor is the newest version seen from home *or* acked by a
        write quorum — a replica that acked a quorum write serves it fresh
        even while home is partitioned.  An unknown floor means the
        catalog never saw the object — only home can be trusted.  A
        negative floor is a deletion: nothing is fresh.
        """
        hv = self.freshness_floor(path)
        if hv is None or hv < 0:
            return []
        return [ep for ep, v in self._holders.get(path, {}).items()
                if v >= hv]


@dataclass
class Replica:
    """One per-site read replica: a HomeStore at its own endpoint.

    Byte accounting (``resident``/``resident_bytes``/``peak``) and the
    touch/fill clocks are maintained for every replica — they are free
    metadata — but only a capacity-bounded replica (an
    :class:`EvictionSpec` on the set) acts on them.
    """

    name: str
    store: HomeStore
    token: str
    lagging: Set[str] = field(default_factory=set)   # paths needing repair
    #: path -> bytes held here (the resident set the eviction scan ranks)
    resident: Dict[str, int] = field(default_factory=dict)
    resident_bytes: int = 0
    #: high-water mark of resident_bytes — the capacity gate's witness
    peak_resident_bytes: int = 0
    #: path -> virtual clock of the last fill this replica served or
    #: received (the LRU clock)
    last_touch: Dict[str, float] = field(default_factory=dict)
    #: path -> cache fills this replica served (the fill-cost signal)
    fills: Dict[str, int] = field(default_factory=dict)
    evictions: int = 0


@dataclass
class PendingApply:
    """One in-flight replica apply: stripes on the wire plus the chained
    ack round-trip.  ``ack.completion`` is when the endpoint counts
    toward the write quorum."""

    name: str
    path: str
    data: bytes
    version: int
    src: str
    group: TransferGroup
    ack: Transfer
    #: vector timestamp riding the apply (None on legacy/untagged paths)
    vts: Optional[Dict[str, int]] = None


class ReplicaSet:
    """Places, routes to, and repairs the read replicas of one home space."""

    def __init__(self, network: Network, home_name: str,
                 home_store: HomeStore, token: str,
                 write_quorum: WritePolicy = 1,
                 queue_aware: bool = True,
                 capacity_bytes: Optional[int] = None,
                 eviction: Optional[EvictionSpec] = None,
                 write_lease: Optional[WriteLeaseSpec] = None,
                 bulk: Optional[BulkSpec] = None):
        if capacity_bytes is not None:
            # deprecated alias (the PR 5 seam): assembles the structured
            # spec — ReplicaPolicy warns; this low-level path stays quiet
            if capacity_bytes <= 0:
                raise ValueError(
                    f"capacity_bytes must be > 0 (or None = unbounded): "
                    f"{capacity_bytes}")
            if eviction is not None and eviction.capacity != capacity_bytes:
                raise ValueError(
                    f"conflicting capacity_bytes={capacity_bytes} and "
                    f"eviction.capacity={eviction.capacity}; drop the "
                    "deprecated alias")
            if eviction is None:
                eviction = EvictionSpec(capacity=capacity_bytes)
        self.network = network
        self.home_name = home_name
        self.home_store = home_store
        self.token = token
        self.write_quorum = write_quorum
        #: Per-replica placement/eviction policy.  None = unbounded:
        #: replicas mirror the whole home space and no accounting is
        #: acted on (traces bit-identical to the pre-eviction fabric).
        self.eviction = eviction
        #: Rank read sources / fan-out targets by estimated completion
        #: (latency + channel queue + NIC backlog).  False restores the
        #: static nearest-by-latency ranking — on an idle network the
        #: two produce identical orders, so this is a load-shedding
        #: feature flag, not a semantics change.
        self.queue_aware = queue_aware
        self.replicas: Dict[str, Replica] = {}
        self.catalog = ReplicaCatalog()
        #: Bulk-transfer policy (repro.core.bulk).  None = legacy
        #: fixed-width striping and home/client-driven repair sources —
        #: traces bit-identical to the pre-bulk fabric.  Set, it widens
        #: apply stripes to the granted stream budget and (with
        #: ``third_party=True``) lets maintenance pull from the
        #: cheapest fresh *replica* instead of home/client.
        self.bulk = bulk
        self.transfer = StripedTransfer(network, spec=bulk)
        #: Per-path write leases for quorum writes (None = lease-free,
        #: vector timestamps alone catch divergence at reconcile).
        self.write_lease = write_lease
        self.fanout_ok = 0
        self.fanout_deferred = 0
        #: applies whose payload moved replica->replica (a third-party
        #: pull from a non-home source), and the ones that fell back to
        #: the mediated path after the chosen source partitioned mid-pull
        self.third_party_pulls = 0
        self.third_party_fallbacks = 0
        self.read_repairs = 0
        self.lease_acquired = 0
        self.lease_contended = 0
        self.lease_unavailable = 0
        #: applies refused because they would overflow a bounded replica
        self.admission_refused = 0
        #: evictions across every replica (per-replica count on Replica)
        self.evictions = 0
        # memoized per-(client, path) fresh-source candidates, valid for
        # one catalog generation; the O(1) lagging membership check and
        # the ranking by current queue state stay per-call (they are
        # O(candidates) — the rebuild of the fresh-holder set was the
        # per-read cost), so lagging mutations and congestion changes
        # take effect immediately without an invalidation hook.
        self._route_cache: Dict[Tuple[str, str], List[str]] = {}
        self._route_cache_gen = -1
        self.route_hits = 0
        self.route_misses = 0
        home_store.subscribe(self._on_home_change)

    @property
    def capacity_bytes(self) -> Optional[int]:
        """Deprecated alias for ``eviction.capacity``; None = unbounded."""
        return self.eviction.capacity if self.eviction is not None else None

    # ---- capacity accounting --------------------------------------------
    # Accounting is unconditional (wire-free dict updates: unbounded-set
    # traces stay bit-identical); only *behavior* — admission, hot-set
    # resync, demand placement, the evict task — gates on ``eviction``.
    def _account_put(self, name: str, path: str, nbytes: int) -> None:
        rep = self.replicas[name]
        old = rep.resident.get(path, 0)
        rep.resident[path] = nbytes
        rep.resident_bytes += nbytes - old
        if rep.resident_bytes > rep.peak_resident_bytes:
            rep.peak_resident_bytes = rep.resident_bytes
        rep.last_touch[path] = self.network.clock
        rep.fills[path] = rep.fills.get(path, 0) + 1

    def _account_drop(self, name: str, path: str) -> int:
        rep = self.replicas[name]
        freed = rep.resident.pop(path, 0)
        rep.resident_bytes -= freed
        rep.last_touch.pop(path, None)
        rep.fills.pop(path, None)
        return freed

    def note_read(self, name: str, path: str) -> None:
        """Touch a path's LRU clock: the client read (or prefetch) was
        served from this replica.  Wire-free; feeds eviction ranking."""
        rep = self.replicas.get(name)
        if rep is not None and path in rep.resident:
            rep.last_touch[path] = self.network.clock

    def admits(self, name: str, path: str, nbytes: int) -> bool:
        """Would landing ``nbytes`` for ``path`` keep the replica within
        its capacity?  Unbounded sets admit everything."""
        if self.eviction is None:
            return True
        rep = self.replicas[name]
        old = rep.resident.get(path, 0)
        return rep.resident_bytes - old + nbytes <= self.eviction.capacity

    # ---- eviction (policy decisions; the fabric schedules them) ----------
    def eviction_protected(self, name: str, path: str) -> bool:
        """Paths eviction must never touch: a quorum-parked write whose
        replica copies are the only durable ones, and a path whose held
        version IS the freshness floor (newer than — or absent from —
        home, so evicting would lose the newest bytes)."""
        if path in self.catalog.quorum_versions:
            return True
        held = self.catalog.version_at(path, name)
        if held is None:
            # physically resident but catalog-dropped (deferred fan-out):
            # repair owns this path, eviction stays away
            return True
        hv = self.catalog.home_version(path)
        return hv is None or held > hv

    def eviction_candidates(self, name: str) -> List[str]:
        """Resident, unprotected paths cheapest-to-evict first under the
        spec's policy: ``"lru"`` = coldest last-touch; ``"fill_cost"`` =
        fewest re-fills (cheap to re-place on demand), LRU tiebreak.
        Path-name tiebreak keeps the order deterministic."""
        rep = self.replicas[name]
        paths = [p for p in rep.resident
                 if not self.eviction_protected(name, p)]
        if self.eviction is not None and self.eviction.policy == "fill_cost":
            paths.sort(key=lambda p: (rep.fills.get(p, 0),
                                      rep.last_touch.get(p, 0.0), p))
        else:
            paths.sort(key=lambda p: (rep.last_touch.get(p, 0.0), p))
        return paths

    def evict_path(self, name: str, path: str) -> int:
        """Drop one replica copy and return the bytes freed.  The path is
        NOT marked lagging: re-placement is read repair on the next hot
        access, not a scheduled repair obligation."""
        rep = self.replicas[name]
        try:
            rep.store.delete(rep.token, path)
        except FileNotFoundError:
            pass
        self.catalog.drop(path, name)
        rep.lagging.discard(path)
        freed = self._account_drop(name, path)
        rep.evictions += 1
        self.evictions += 1
        return freed

    # ---- write-ack policy ------------------------------------------------
    @property
    def n_endpoints(self) -> int:
        """Size of the write group: home + every placed replica."""
        return 1 + len(self.replicas)

    def resolve_w(self) -> int:
        """Acks required before the flusher may retire a write."""
        n = self.n_endpoints
        if self.write_quorum == "majority":
            return n // 2 + 1
        if self.write_quorum == "all":
            return n
        return max(1, min(int(self.write_quorum), n))

    def next_version(self, path: str) -> int:
        """Client-assigned version for a quorum write around a dead home:
        one past the newest version any endpoint is known to hold."""
        best = 0
        hv = self.catalog.home_version(path)
        if hv is not None and hv > best:
            best = hv
        qv = self.catalog.quorum_versions.get(path)
        if qv is not None and qv > best:
            best = qv
        for ep in self.replicas:
            v = self.catalog.version_at(path, ep)
            if v is not None and v > best:
                best = v
        return best + 1

    # ---- concurrent-writer safety ---------------------------------------
    def vts_frontier(self, client_name: str, path: str) -> Dict[str, int]:
        """Merged vector-timestamp frontier of every replica reachable
        from ``client_name``.  The frontier piggy-backs on the fan-out
        messages the flusher sends anyway, so reading it is wire-free;
        merging it into a new write's stamp is what orders that write
        *after* everything a common replica has already acked."""
        out: Dict[str, int] = {}
        for name, rep in self.replicas.items():
            if self.network.is_partitioned(client_name, name):
                continue
            out = vts_merge(out, rep.store.vts_of(path))
        return out

    def acquire_write_lease(self, client_name: str, path: str,
                            owner: str) -> Optional[bool]:
        """Take the per-path write lease on every reachable replica.

        Returns ``True`` when all reachable replicas granted (same-owner
        re-acquire extends — a resumed flush attempt keeps its lease),
        ``False`` when another writer holds it somewhere (partial grants
        are rolled back; the caller defers), and ``None`` when no
        replica is reachable at all — a full partition, where the lease
        cannot serialize anything and vector timestamps are the safety
        net.  Each grant and rollback is a real lease RPC on the clock.
        """
        spec = self.write_lease
        if spec is None:
            return None
        reachable = [n for n in self.replicas
                     if not self.network.is_partitioned(client_name, n)]
        if not reachable:
            self.lease_unavailable += 1
            return None
        granted: List[str] = []
        for name in reachable:
            rep = self.replicas[name]
            try:
                self.network.rpc(client_name, name, "write_lease")
            except DisconnectedError:
                continue          # flapped mid-acquire: treat as absent
            if rep.store.acquire_lock(rep.token, path, owner,
                                      spec.ttl_s, self.network.clock):
                granted.append(name)
                continue
            # contended: another writer got there first on a common
            # replica — roll back partial grants and defer
            for g in granted:
                grep = self.replicas[g]
                try:
                    self.network.rpc(client_name, g, "write_lease_release")
                except DisconnectedError:
                    pass          # its short TTL is the fallback
                grep.store.release_lock(grep.token, path, owner)
            self.lease_contended += 1
            return False
        if not granted:
            self.lease_unavailable += 1
            return None
        self.lease_acquired += 1
        return True

    def release_write_lease(self, client_name: str, path: str,
                            owner: str) -> int:
        """Best-effort release of a held write lease (called once the
        write lands at home).  A replica that cannot be reached keeps
        the lock until its TTL lapses — crash-safe by construction."""
        released = 0
        now = self.network.clock
        for name, rep in self.replicas.items():
            if rep.store.lock_owner(path, now) != owner:
                continue
            try:
                self.network.rpc(client_name, name, "write_lease_release")
            except DisconnectedError:
                continue          # TTL expiry is the fallback
            rep.store.release_lock(rep.token, path, owner)
            released += 1
        return released

    def _route_cost(self, src: str, dst: str, nbytes: int) -> float:
        """What one routing candidate costs right now: estimated
        completion (latency + channel queue + NIC backlog) when
        queue-aware, static link latency otherwise."""
        if self.queue_aware:
            return self.network.estimated_completion(src, dst, nbytes)
        return self.network.latency_between(src, dst)

    def _route_costs(self, src: str, dsts: List[str],
                     nbytes: int) -> List[float]:
        """Costs of many candidates in one pass: one vectorized
        ``estimate_batch`` call when queue-aware (element-identical to
        per-candidate ``estimated_completion``), static latencies
        otherwise."""
        if not dsts:
            return []
        if self.queue_aware:
            return self.network.estimate_batch(src, dsts, nbytes).tolist()
        return [self.network.latency_between(src, d) for d in dsts]

    def replicas_by_cost(self, src: str, nbytes: int = 0) -> List[str]:
        """Replica names cheapest-first from ``src`` under the current
        queue/NIC state — the flusher launches fan-out in this order so
        the W-th ack lands as early as possible.  Partitioned pairs
        estimate to ``inf`` and sort last (they defer anyway)."""
        names = list(self.replicas)
        costs = self._route_costs(src, names, nbytes)
        # stable sort on cost == sorted(key=cost): ties keep replica order
        return [n for _c, n in sorted(zip(costs, names),
                                      key=lambda cn: cn[0])]

    # ---- catalog feed (rides the home callback channel) ------------------
    def _on_home_change(self, path: str, st: ObjectStat) -> None:
        self.catalog.note_home(path, st.version)

    def reattach(self, token: Optional[str] = None,
                 via: Optional[str] = None,
                 skip: Optional[Set[str]] = None) -> bool:
        """Recover the fabric view after a home-server crash.

        Re-subscribes the catalog feed (the crash dropped it) and
        re-learns the home version vector, which the catalog may have
        missed changes to while the channel was down.  ``token`` replaces
        an auth token the crash invalidated; ``via`` names the endpoint
        whose link to home gates the refresh — when that link is still
        partitioned the quorum-side view simply survives untouched;
        ``skip`` marks quorum-parked paths whose freshness floor must not
        be evicted before reconciliation lands them at home.
        Returns True when the home vector was re-learned.
        """
        if token is not None:
            self.token = token
        self.home_store.unsubscribe(self._on_home_change)
        self.home_store.subscribe(self._on_home_change)
        if via is not None and self.network.is_partitioned(via,
                                                           self.home_name):
            return False
        try:
            vv = self.home_store.version_vector(self.token)
        except (AuthError, DisconnectedError):
            return False   # still crashed / token stale: survive, and let
            #                Session.remount re-authenticate
        for path, hv in vv.items():
            if skip is None or path not in skip:
                self.catalog.note_home(path, hv)
        self.catalog.vector_learned = True
        return True

    # ---- placement -------------------------------------------------------
    def add_replica(self, name: str, store: HomeStore) -> Replica:
        token = store.authenticate(
            lambda ch: respond(store.keyphrase, ch))
        rep = Replica(name=name, store=store, token=token)
        self.replicas[name] = rep
        return rep

    # ---- read routing ----------------------------------------------------
    def _fresh_sources(self, client_name: str, path: str) -> List[str]:
        """Memoized replica candidates (fresh holders placed in this
        set) for one (client, path); valid for exactly one catalog
        generation — any note/record/drop clears the cache wholesale.
        Lagging is deliberately NOT baked in: it is an O(1) membership
        test the caller applies per-call, so every mutation spelling on
        a plain ``lagging`` set takes effect immediately."""
        if self.catalog.gen != self._route_cache_gen:
            self._route_cache.clear()
            self._route_cache_gen = self.catalog.gen
        key = (client_name, path)
        names = self._route_cache.get(key)
        if names is not None:
            self.route_hits += 1
            return names
        self.route_misses += 1
        names = [ep for ep in self.catalog.fresh_holders(path)
                 if ep in self.replicas]
        self._route_cache[key] = names
        return names

    def route(self, client_name: str, path: str,
              nbytes: Optional[int] = None) -> List[ReadSource]:
        """Read sources cheapest-first by estimated completion (static
        latency when ``queue_aware`` is off); home always present.

        ``nbytes`` prices the candidates with the object size when the
        caller knows it (prefetch does); otherwise a nominal probe size
        stands in.  Cost ties go to home (authoritative).  The client
        walks the list, falling back on :class:`DisconnectedError`.
        """
        probe = ROUTE_PROBE_BYTES if nbytes is None else nbytes
        cands: List[Tuple[int, ReadSource]] = [
            (0, (self.home_name, self.home_store, self.token))]
        for ep in self._fresh_sources(client_name, path):
            rep = self.replicas[ep]
            if path in rep.lagging:
                continue
            cands.append((1, (ep, rep.store, rep.token)))
        # every candidate priced in one vectorized pass
        costs = self._route_costs(client_name, [s[0] for _t, s in cands],
                                  probe)
        ranked = [(c, t, s) for c, (t, s) in zip(costs, cands)]
        ranked.sort(key=lambda t: (t[0], t[1]))
        return [src for _, _, src in ranked]

    # ---- metadata routing ------------------------------------------------
    def route_meta(self, client_name: str, prefix: str) -> List[ReadSource]:
        """Metadata read sources (``stat`` via listing / ``opendir``)
        cheapest-first by the same estimated-completion rule as data
        reads; home always present as the authoritative fallback
        regardless of its queue depth.

        A replica may serve a *listing* only when the catalog can prove it
        complete and fresh for the prefix: the full home version vector
        has been learned at least once (``vector_learned`` — an
        incremental change feed alone cannot rule out objects that
        predate the subscription), every known path under the prefix with
        a live freshness floor is held at >= that floor, and no deferred
        fan-out (``lagging``) touches the prefix.  A catalog that knows
        nothing under the prefix proves nothing — metadata then routes
        home (``resync()``/``reattach()`` teach it the home vector).
        """
        cands: List[Tuple[int, ReadSource]] = [
            (0, (self.home_name, self.home_store, self.token))]
        # directory match, not raw string prefix: "home/meta2/x" must not
        # count against a listing of "home/meta" — served by the
        # catalog's per-directory index, not a scan of every known path
        dirp = prefix if prefix.endswith("/") else prefix + "/"
        need = [(p, self.catalog.freshness_floor(p))
                for p in sorted(self.catalog.paths_under(dirp))]
        need = [(p, fl) for p, fl in need if fl is not None and fl >= 0]
        if need and self.catalog.vector_learned:
            for name, rep in self.replicas.items():
                if any(p.startswith(dirp) for p in rep.lagging):
                    continue
                if all((self.catalog.version_at(p, name) or 0) >= fl
                       for p, fl in need):
                    cands.append((1, (name, rep.store, rep.token)))
        costs = self._route_costs(client_name, [s[0] for _t, s in cands], 0)
        ranked = [(c, t, s) for c, (t, s) in zip(costs, cands)]
        ranked.sort(key=lambda t: (t[0], t[1]))
        return [src for _, _, src in ranked]

    # ---- third-party source selection (repro.core.bulk) ------------------
    def third_party_source(self, target: str, path: str, version: int,
                           nbytes: int) -> Optional[str]:
        """Cheapest endpoint already holding exactly ``version`` of
        ``path`` to drive a repair of ``target`` from — a replica or
        home, ranked by the same queue-aware cost the read router uses
        (``estimate_batch``: latency + channel queue + NIC backlog), so
        a maintenance drain spreads across source NICs instead of
        serializing through home.  GridFTP's third-party transfer: the
        orchestrating session stays off the data path entirely.

        Returns ``None`` when the bulk plane is off (``bulk`` unset or
        ``third_party=False``) or no reachable holder exists — callers
        then keep their legacy source (home for resync/repair, the
        reading client for read repair).  Ties prefer a replica over
        home (the offload is the point), then name order.
        """
        spec = self.bulk
        if spec is None or not spec.third_party:
            return None
        cands: List[str] = []
        for ep, rep in self.replicas.items():
            if ep == target or path in rep.lagging:
                continue
            if self.catalog.version_at(path, ep) == version:
                cands.append(ep)
        if self.catalog.home_version(path) == version:
            cands.append(self.home_name)
        if not cands:
            return None
        costs = self._route_costs(target, cands, nbytes)
        ranked = sorted(zip(costs, cands),
                        key=lambda ce: (ce[0], ce[1] == self.home_name,
                                        ce[1]))
        for cost, ep in ranked:
            if cost != float("inf"):      # partitioned pairs price to inf
                return ep
        return None

    # ---- write-back fan-out ---------------------------------------------
    def begin_apply(self, name: str, path: str, data: bytes,
                    version: int, src: Optional[str] = None,
                    vts: Optional[Dict[str, int]] = None,
                    fallback_src: Optional[str] = None
                    ) -> Optional[PendingApply]:
        """Launch one replica apply as overlapped channel reservations.

        ``src`` is the endpoint driving the apply: home during ordinary
        fan-out and resync (third-party transfer, GridFTP-style), a
        fresh replica when :meth:`third_party_source` found a cheaper
        holder, or the client site when the flusher assembles a quorum
        around a partitioned home.  The data stripes and the chained ack
        ride the same pair (the ack reserves ``not_before`` the data
        lands), so per-pair accounting shows where quorum round-trips
        went.  A partitioned replica is recorded as lagging and yields
        ``None`` — fan-out never blocks or fails the flusher on a WAN
        fault; when a *third-party source* is what partitioned,
        ``fallback_src`` retries once through the mediated path instead
        (a repair must not stall on a second fault domain).  The clock
        does not move; pair :meth:`complete_apply` with a
        ``network.wait`` when the caller needs the ack on the clock.
        """
        rep = self.replicas[name]
        if not self.admits(name, path, len(data)):
            # bounded replica full: refuse, don't reserve wire.  The old
            # resident version (if any) stays valid — no catalog drop —
            # and the path must NOT stay lagging or the scheduled repair
            # would spin on a refusal forever; the evict task frees room
            # and the next hot read re-places via read repair.
            rep.lagging.discard(path)
            self.admission_refused += 1
            return None
        src = src or self.home_name
        try:
            group = self.transfer.begin(src, name, data)
            ack = self.network.transfer(name, src, "write_ack",
                                        not_before=group.completion)
        except DisconnectedError:
            if fallback_src is not None and fallback_src != src:
                self.third_party_fallbacks += 1
                return self.begin_apply(name, path, data, version,
                                        src=fallback_src, vts=vts)
            rep.lagging.add(path)
            self.catalog.drop(path, name)
            self.fanout_deferred += 1
            return None
        if src != self.home_name and src in self.replicas:
            self.third_party_pulls += 1
        self.network.note_provenance(
            "third_party" if (src == self.home_name
                              or src in self.replicas)
            else "client_mediated", len(data))
        return PendingApply(name=name, path=path, data=data,
                            version=version, src=src, group=group, ack=ack,
                            vts=vts)

    def complete_apply(self, p: PendingApply) -> None:
        """Land one in-flight apply: real bytes into the replica store,
        catalog updated, lag cleared.  Does not touch the clock — the
        caller decides whether this ack is on the critical path."""
        rep = self.replicas[p.name]
        rep.store.put(rep.token, p.path, p.data, version=p.version)
        if p.vts is not None:
            rep.store.set_vts(p.path, p.vts)
        self.catalog.record(p.path, p.name, p.version)
        rep.lagging.discard(p.path)
        self._account_put(p.name, p.path, len(p.data))
        self.fanout_ok += 1

    def apply_to_replica(self, name: str, path: str, data: bytes,
                         version: int, src: Optional[str] = None,
                         vts: Optional[Dict[str, int]] = None,
                         fallback_src: Optional[str] = None) -> bool:
        """Blocking apply (anti-entropy repair path): launch, wait the
        ack onto the clock, land the bytes."""
        p = self.begin_apply(name, path, data, version, src=src, vts=vts,
                             fallback_src=fallback_src)
        if p is None:
            return False
        self.network.wait(p.ack)
        self.complete_apply(p)
        return True

    # ---- read repair -----------------------------------------------------
    def read_repair(self, client_name: str, path: str, data: bytes,
                    version: int,
                    vts: Optional[Dict[str, int]] = None) -> int:
        """Push freshly-read bytes to replicas observed stale, off the
        reader's critical path.

        A quorum read that routed past a stale or lagging replica already
        has the fresh bytes in hand — pushing them back over the same
        striped-transfer fabric repairs the replica *now* instead of
        waiting for the next anti-entropy ``resync()``.  The pushes are
        overlapped channel reservations that are never waited on, so the
        read's observed latency is untouched.  Guards: never push bytes
        older than the freshness floor (a stale read must not propagate),
        and never touch a replica already at or past ``version``.
        """
        floor = self.catalog.freshness_floor(path)
        if floor is not None and version < floor:
            return 0
        repaired = 0
        for name, rep in self.replicas.items():
            held = self.catalog.version_at(path, name)
            if held is not None and held >= version:
                continue
            if held is None and path not in rep.lagging \
                    and self.eviction is None:
                continue          # never placed here: placement, not repair
            # on a capacity-bounded replica the read reaching this point
            # IS the placement signal: the path is hot, so read repair
            # doubles as demand placement (admission still gates it)
            tp = self.third_party_source(name, path, version, len(data))
            src = tp if tp is not None else client_name
            p = self.begin_apply(
                name, path, data, version, src=src, vts=vts,
                fallback_src=client_name if tp is not None else None)
            if p is None:
                continue          # still partitioned: stays lagging
            self.complete_apply(p)
            repaired += 1
        self.read_repairs += repaired
        return repaired

    def propagate_delete(self, path: str) -> int:
        ok = 0
        for rep in self.replicas.values():
            try:
                self.network.rpc(self.home_name, rep.name, "replica_delete")
            except DisconnectedError:
                rep.lagging.add(path)
                self.catalog.drop(path, rep.name)
                self.fanout_deferred += 1
                continue
            try:
                rep.store.delete(rep.token, path)
            except FileNotFoundError:
                pass
            self.catalog.drop(path, rep.name)
            rep.lagging.discard(path)
            self._account_drop(rep.name, path)
            ok += 1
        return ok

    # ---- anti-entropy ----------------------------------------------------
    def resync(self, skip: Optional[Set[str]] = None) -> int:
        """Converge every replica onto the home version vector.

        Pushes missing/stale objects, removes deleted ones, and refreshes
        the catalog's home-version view (which also recovers from a home
        crash that dropped the notification subscription).  ``skip`` names
        paths with a quorum-parked write still awaiting home
        reconciliation: home's numerically-higher-but-older version must
        not overwrite the acked replica bytes or evict the quorum
        freshness floor.  Returns the number of repair transfers.
        """
        skip = skip or set()
        vv = self.home_store.version_vector(self.token)
        for path, hv in vv.items():
            if path not in skip:
                self.catalog.note_home(path, hv)
        self.catalog.vector_learned = True
        repaired = 0
        for path, hv in vv.items():
            if path in skip:
                continue
            blob = None       # home disk read shared across replicas
            target = hv
            for rep in self.replicas.values():
                if self.eviction is not None \
                        and path not in rep.resident \
                        and path not in rep.lagging:
                    # hot-set-only fill: a capacity-bounded replica never
                    # mirrors at resync — bytes arrive on demand (read
                    # repair) and anti-entropy only refreshes what is
                    # already resident or owed (lagging)
                    continue
                held = self.catalog.version_at(path, rep.name)
                if held is not None and held >= target:
                    rep.lagging.discard(path)
                    continue
                if blob is None:
                    try:
                        blob = self.home_store.get(self.token, path)
                    except FileNotFoundError:
                        break   # deleted since the vector snapshot
                    data, st = blob
                    if st.version != target:
                        # a home write landed between the vector snapshot
                        # and this fetch: the fetched bytes are what every
                        # replica receives, so the fetched version is what
                        # the catalog must pin — judging staleness by the
                        # snapshot while applying the newer version left
                        # the catalog's home view and the replica holdings
                        # permanently divergent (visible whenever the
                        # change-feed subscription is down, i.e. exactly
                        # the post-crash recovery resync() serves)
                        target = st.version
                        self.catalog.note_home(path, target)
                        if held is not None and held >= target:
                            rep.lagging.discard(path)
                            continue
                data, st = blob
                # a replica already converged this pass is a third-party
                # source for the next one — the catalog records it at
                # complete_apply, so selection sees it immediately
                tp = self.third_party_source(rep.name, path, st.version,
                                             len(data))
                if self.apply_to_replica(
                        rep.name, path, data, st.version, src=tp,
                        vts=self.home_store.vts_of(path) or None,
                        fallback_src=self.home_name
                        if tp not in (None, self.home_name) else None):
                    repaired += 1
        for rep in self.replicas.values():
            # drop objects deleted at home (a parked quorum write that home
            # has never seen is NOT deleted-at-home — its replica copies
            # are the only durable ones)
            for path in self.catalog.paths_at(rep.name):
                if path in vv or path in skip:
                    continue
                try:
                    self.network.rpc(self.home_name, rep.name,
                                     "replica_delete")
                except DisconnectedError:
                    rep.lagging.add(path)
                    continue
                try:
                    rep.store.delete(rep.token, path)
                except FileNotFoundError:
                    pass
                self.catalog.drop(path, rep.name)
                # mirror propagate_delete: a successfully deleted path is
                # repaired — leaving it in ``lagging`` kept a dead path on
                # the read-repair candidate list forever
                rep.lagging.discard(path)
                self._account_drop(rep.name, path)
                repaired += 1
        return repaired

    # ---- schedulable maintenance units -----------------------------------
    def repair_targets(self) -> List[str]:
        """Paths some replica still needs repaired (deferred fan-out and
        partition leftovers), sorted so the scheduled drain walks them in
        a deterministic order."""
        out: Set[str] = set()
        for rep in self.replicas.values():
            out |= rep.lagging
        return sorted(out)

    def begin_repair_path(self, path: str) -> List[PendingApply]:
        """Launch — without waiting — the repair of ONE path onto every
        replica that lags or trails it: the schedulable read-repair
        drain unit.

        Storage-driven pushes (home, or the cheapest fresh replica when
        the bulk plane's third-party selection is armed — see
        :meth:`third_party_source`), overlapped channel reservations;
        the caller (the maintenance scheduler) completes each apply via
        :meth:`complete_apply` when its ack matures, so repair wire time
        never rides a reader's clock.  A path deleted at home while the
        repair was queued drains the tombstone instead
        (:meth:`propagate_delete`).  A still-partitioned replica stays
        lagging — the next drain tick retries.
        """
        try:
            data, st = self.home_store.get(self.token, path)
        except FileNotFoundError:
            self.propagate_delete(path)
            return []
        # same pin rule as resync(): the fetched version is the target
        self.catalog.note_home(path, st.version)
        pending: List[PendingApply] = []
        for name, rep in self.replicas.items():
            held = self.catalog.version_at(path, name)
            if held is not None and held >= st.version:
                rep.lagging.discard(path)
                continue
            if path not in rep.lagging and held is None:
                continue      # never placed here: placement, not repair
            tp = self.third_party_source(name, path, st.version, len(data))
            p = self.begin_apply(
                name, path, data, st.version, src=tp,
                vts=self.home_store.vts_of(path) or None,
                fallback_src=self.home_name
                if tp not in (None, self.home_name) else None)
            if p is not None:
                pending.append(p)
        return pending
