"""Multi-site replica fabric: catalog, nearest-replica reads, fan-out.

XUFS as published assumes a single authoritative home store; this module
adds SCISPACE-style per-site read replicas on top of the same
``Network``/``HomeStore`` fabric, following the GridFTP replica-management
recipe (replica catalog + striped transfer):

  * :class:`ReplicaCatalog` maps ``path -> {endpoint: version}`` plus the
    home's latest version per path.  A holder is *fresh* iff its version is
    at least the home version the catalog has seen — callback notifications
    from the home store keep the catalog current, so a stale replica drops
    out of the read path the moment home changes (the replica-side
    equivalent of ``cache.INVALID``).
  * :class:`ReplicaSet` places the replicas, routes reads to the
    lowest-latency fresh holder (home is always the terminal fallback),
    fans writes out home-first-then-replicas so a lagging or partitioned
    replica never blocks the client, and repairs divergence via
    ``resync()`` (anti-entropy over the home version vector).

The catalog is metadata colocated with the home service and mirrored to
clients over the callback channel; lookups are therefore modeled as free —
only data movement and per-operation RPCs charge the virtual clock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from repro.core.store import HomeStore, ObjectStat
from repro.core.striping import StripedTransfer
from repro.core.transport import DisconnectedError, Network, respond

#: A read source the client can try: (endpoint name, store, auth token).
ReadSource = Tuple[str, HomeStore, str]


class ReplicaCatalog:
    """``path -> {endpoint: version}`` plus the home version per path."""

    def __init__(self) -> None:
        self.home_versions: Dict[str, int] = {}
        self._holders: Dict[str, Dict[str, int]] = {}

    # ---- home side -------------------------------------------------------
    def note_home(self, path: str, version: int) -> None:
        self.home_versions[path] = version

    def home_version(self, path: str) -> Optional[int]:
        return self.home_versions.get(path)

    # ---- holders ---------------------------------------------------------
    def record(self, path: str, endpoint: str, version: int) -> None:
        self._holders.setdefault(path, {})[endpoint] = version

    def drop(self, path: str, endpoint: Optional[str] = None) -> None:
        if endpoint is None:
            self._holders.pop(path, None)
            return
        holders = self._holders.get(path)
        if holders is not None:
            holders.pop(endpoint, None)

    def version_at(self, path: str, endpoint: str) -> Optional[int]:
        return self._holders.get(path, {}).get(endpoint)

    def paths_at(self, endpoint: str) -> List[str]:
        return [p for p, h in self._holders.items() if endpoint in h]

    def fresh_holders(self, path: str) -> List[str]:
        """Endpoints holding a version at least as new as home's.

        Unknown home version means the catalog never saw the object — only
        home can be trusted.  A negative home version is a deletion: nothing
        is fresh.
        """
        hv = self.home_versions.get(path)
        if hv is None or hv < 0:
            return []
        return [ep for ep, v in self._holders.get(path, {}).items()
                if v >= hv]


@dataclass
class Replica:
    """One per-site read replica: a HomeStore at its own endpoint."""

    name: str
    store: HomeStore
    token: str
    lagging: Set[str] = field(default_factory=set)   # paths needing repair


class ReplicaSet:
    """Places, routes to, and repairs the read replicas of one home space."""

    def __init__(self, network: Network, home_name: str,
                 home_store: HomeStore, token: str):
        self.network = network
        self.home_name = home_name
        self.home_store = home_store
        self.token = token
        self.replicas: Dict[str, Replica] = {}
        self.catalog = ReplicaCatalog()
        self.transfer = StripedTransfer(network)
        self.fanout_ok = 0
        self.fanout_deferred = 0
        home_store.subscribe(self._on_home_change)

    # ---- catalog feed (rides the home callback channel) ------------------
    def _on_home_change(self, path: str, st: ObjectStat) -> None:
        self.catalog.note_home(path, st.version)

    def reattach(self) -> None:
        """Re-subscribe after a home-server crash dropped subscriptions."""
        self.home_store.unsubscribe(self._on_home_change)
        self.home_store.subscribe(self._on_home_change)

    # ---- placement -------------------------------------------------------
    def add_replica(self, name: str, store: HomeStore) -> Replica:
        token = store.authenticate(
            lambda ch: respond(store.keyphrase, ch))
        rep = Replica(name=name, store=store, token=token)
        self.replicas[name] = rep
        return rep

    # ---- read routing ----------------------------------------------------
    def route(self, client_name: str, path: str) -> List[ReadSource]:
        """Read sources ordered by link latency; home always present.

        Ties go to home (authoritative).  The client walks the list,
        falling back on :class:`DisconnectedError`.
        """
        ranked: List[Tuple[float, int, ReadSource]] = [(
            self.network.latency_between(client_name, self.home_name), 0,
            (self.home_name, self.home_store, self.token))]
        for ep in self.catalog.fresh_holders(path):
            rep = self.replicas.get(ep)
            if rep is None or path in rep.lagging:
                continue
            ranked.append((self.network.latency_between(client_name, ep), 1,
                           (ep, rep.store, rep.token)))
        ranked.sort(key=lambda t: (t[0], t[1]))
        return [src for _, _, src in ranked]

    # ---- write-back fan-out ---------------------------------------------
    def propagate(self, path: str, data: bytes, st: ObjectStat) -> int:
        """Push one home-applied store to every replica (home -> replica).

        A partitioned replica is recorded as lagging and skipped — fan-out
        never blocks or fails the flusher on a WAN fault.  Returns the
        number of replicas brought fresh.
        """
        ok = 0
        for rep in self.replicas.values():
            try:
                self.transfer.send(self.home_name, rep.name, data)
            except DisconnectedError:
                rep.lagging.add(path)
                self.catalog.drop(path, rep.name)
                self.fanout_deferred += 1
                continue
            rep.store.put(rep.token, path, data, version=st.version)
            self.catalog.record(path, rep.name, st.version)
            rep.lagging.discard(path)
            self.fanout_ok += 1
            ok += 1
        return ok

    def propagate_delete(self, path: str) -> int:
        ok = 0
        for rep in self.replicas.values():
            try:
                self.network.rpc(self.home_name, rep.name, "replica_delete")
            except DisconnectedError:
                rep.lagging.add(path)
                self.catalog.drop(path, rep.name)
                self.fanout_deferred += 1
                continue
            try:
                rep.store.delete(rep.token, path)
            except FileNotFoundError:
                pass
            self.catalog.drop(path, rep.name)
            rep.lagging.discard(path)
            ok += 1
        return ok

    # ---- anti-entropy ----------------------------------------------------
    def resync(self) -> int:
        """Converge every replica onto the home version vector.

        Pushes missing/stale objects, removes deleted ones, and refreshes
        the catalog's home-version view (which also recovers from a home
        crash that dropped the notification subscription).  Returns the
        number of repair transfers performed.
        """
        vv = self.home_store.version_vector(self.token)
        for path, hv in vv.items():
            self.catalog.note_home(path, hv)
        repaired = 0
        for path, hv in vv.items():
            blob = None       # home disk read shared across replicas
            for rep in self.replicas.values():
                held = self.catalog.version_at(path, rep.name)
                if held is not None and held >= hv:
                    rep.lagging.discard(path)
                    continue
                if blob is None:
                    try:
                        blob = self.home_store.get(self.token, path)
                    except FileNotFoundError:
                        break   # deleted since the vector snapshot
                data, st = blob
                try:
                    self.transfer.send(self.home_name, rep.name, data)
                except DisconnectedError:
                    rep.lagging.add(path)
                    continue
                rep.store.put(rep.token, path, data, version=st.version)
                self.catalog.record(path, rep.name, st.version)
                rep.lagging.discard(path)
                repaired += 1
        for rep in self.replicas.values():
            # drop objects deleted at home
            for path in self.catalog.paths_at(rep.name):
                if path in vv:
                    continue
                try:
                    self.network.rpc(self.home_name, rep.name,
                                     "replica_delete")
                except DisconnectedError:
                    rep.lagging.add(path)
                    continue
                try:
                    rep.store.delete(rep.token, path)
                except FileNotFoundError:
                    pass
                self.catalog.drop(path, rep.name)
                repaired += 1
        return repaired
