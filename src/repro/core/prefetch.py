"""Parallel small-file pre-fetch (paper §3.3).

On first ``chdir`` into a mounted directory, up to ``MAX_WORKERS`` (12)
parallel streams fetch every file smaller than 64 KB.  The virtual clock is
charged wave-by-wave (12 fetches proceed concurrently), which is what makes
the paper's Fig. 4 source-build workload fast on first touch.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core.cache import VALID, DIRTY
from repro.core.store import ObjectStat
from repro.core.transport import DisconnectedError

SMALL_FILE = 64 * 1024
MAX_WORKERS = 12


@dataclass
class Prefetcher:
    client: "XufsClient"          # noqa: F821 (circular-light)
    max_workers: int = MAX_WORKERS
    small_file: int = SMALL_FILE

    def prefetch_small(self, prefix: str, stats: List[ObjectStat]) -> int:
        cl = self.client
        todo = []
        for st in stats:
            if st.is_dir or st.size >= self.small_file:
                continue
            entry = cl.cache.lookup(st.path)
            if entry is not None and entry.state in (VALID, DIRTY) \
                    and entry.stat.version >= st.version:
                continue
            todo.append(st)
        if not todo:
            return 0

        m = cl._mount_for(todo[0].path)
        fetched = 0
        fetched_bytes = 0
        clock0 = cl.network.clock
        wave_times: List[float] = []
        for i in range(0, len(todo), self.max_workers):
            wave = todo[i:i + self.max_workers]
            t_wave = 0.0
            for st in wave:
                # nearest fresh replica first; home is the terminal source
                data = fresh = src = None
                for server_name, store, token in cl._read_sources(m, st.path):
                    if cl.network.is_partitioned(cl.name, server_name):
                        continue
                    try:
                        data, fresh = store.get(token, st.path)
                    except FileNotFoundError:
                        continue
                    src = server_name
                    break
                if data is None:
                    continue
                # each worker is an independent single stream; the wave's
                # wall time is the max over its members.
                t = cl.network.link_between(cl.name, src).transfer_time(
                    len(data), n_streams=1)
                t_wave = max(t_wave, t)
                cl.cache.store_data(st.path, data, fresh, state=VALID)
                cl.cache.misses += 1
                cl.cache.record_fill(src)
                cl.network.account(src, len(data))
                cl.network.account(cl.name, len(data))
                fetched += 1
                fetched_bytes += len(data)
            wave_times.append(t_wave)
        # charge the clock for the parallel waves (not the serial sum)
        cl.network.clock = clock0 + sum(wave_times)
        cl.network.rpc_count += fetched
        cl.network.bytes_sent += fetched_bytes
        return fetched
