"""Pipelined small-file pre-fetch (paper §3.3).

On first ``chdir`` into a mounted directory, every file smaller than
64 KB is fetched over the simulated transport.  Each fill is a
single-stream channel reservation on the (client, source) pair; the
channel clock pipelines them — up to ``Network.channels_per_pair`` (12)
fills proceed concurrently and the 13th queues behind the earliest-free
channel — so the elapsed time is the max over channel queues, not the
serial sum.  That is what makes the paper's Fig. 4 source-build workload
fast on first touch.  Fills route to the fresh replica with the lowest
estimated completion when a replica fabric is mounted (so a saturating
source sheds later fills to the next-cheapest holder); sources on
different pairs overlap fully.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from repro.core.cache import VALID, DIRTY
from repro.core.store import ObjectStat
from repro.core.transport import (
    DisconnectedError, Transfer, TransferRequest,
)

SMALL_FILE = 64 * 1024


@dataclass
class Prefetcher:
    client: "XufsClient"          # noqa: F821 (circular-light)
    small_file: int = SMALL_FILE

    def prefetch_small(self, prefix: str, stats: List[ObjectStat]) -> int:
        cl = self.client
        todo = []
        for st in stats:
            if st.is_dir or st.size >= self.small_file:
                continue
            entry = cl.cache.lookup(st.path)
            if entry is not None and entry.state in (VALID, DIRTY) \
                    and entry.stat.version >= st.version:
                continue
            todo.append(st)
        if not todo:
            return 0

        m = cl._mount_for(todo[0].path)
        # queue-aware replica routing prices each fill against the live
        # channel state INCLUDING the fills already issued (that is the
        # load-shedding feedback loop) — those must keep reserving
        # inline.  Static routing reads no queue state, so the whole
        # wave can be reserved as one same-epoch batch at the end —
        # bit-identical reservations, one event-queue entry.
        batched = m.replicas is None or not m.replicas.queue_aware
        fetched = 0
        transfers: List[Transfer] = []
        reqs: List[TransferRequest] = []
        for st in todo:
            # cheapest fresh source first (the route is priced with the
            # file's actual size, so queue depth and NIC backlog from
            # the fills already issued steer later fills away from a
            # saturating source); home is the terminal source
            data = fresh = src = None
            for server_name, store, token in cl._read_sources(
                    m, st.path, nbytes=st.size):
                if cl.network.is_partitioned(cl.name, server_name):
                    continue
                try:
                    data, fresh = store.get(token, st.path)
                except FileNotFoundError:
                    continue
                src = server_name
                break
            if data is None:
                continue
            # one stream per fill, pipelined over the pair's channel pool
            if batched:
                reqs.append(
                    TransferRequest(src, cl.name, "prefetch", len(data)))
            else:
                transfers.append(
                    cl.network.transfer(src, cl.name, "prefetch",
                                        len(data)))
            cl.cache.store_data(st.path, data, fresh, state=VALID)
            cl.cache.misses += 1
            cl.cache.record_fill(src)
            if m.replicas is not None:
                # a prefetch hit is a read for LRU purposes (wire-free)
                m.replicas.note_read(src, st.path)
            fetched += 1
        # block until the last fill lands: overlapped elapsed, not the sum
        if reqs:
            cl.network.wait_batch(cl.network.transfer_batch(reqs))
        cl.network.wait_all(transfers)
        return fetched
