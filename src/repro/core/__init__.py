"""XUFS core fabric: the paper's contribution as a composable library.

The documented public surface is ``__all__``; ``tests/test_public_api.py``
holds it stable.  Topology is declared with the spec layer
(:class:`FabricSpec` et al., ``docs/fabric.md``); ``ussh_login`` survives
only as a deprecated shim over it.
"""
from repro.core.transport import (  # noqa: F401
    Network, Endpoint, LinkModel, Transfer, TransferBatch, TransferRequest,
    KeyPhrase, DisconnectedError, AuthError, QuorumNotReachedError,
    KB, MB, GB,
)
from repro.core.striping import (  # noqa: F401
    plan_stripes, reassemble, StripePlan, StripedTransfer, TransferGroup,
    STRIPE_THRESHOLD, MIN_BLOCK, MAX_STRIPES,
)
from repro.core.bulk import (  # noqa: F401
    BulkResult, BulkSpec, BulkTransfer, ensure_channel_width,
    grant_streams,
)
from repro.core.store import HomeStore, ObjectStat  # noqa: F401
from repro.core.cache import CacheSpace, CacheEntry, CacheStats  # noqa: F401
from repro.core.oplog import (  # noqa: F401
    MetaOpQueue, OpRecord, vts_concurrent, vts_dominates, vts_merge,
)
from repro.core.callbacks import NotificationManager  # noqa: F401
from repro.core.replication import (  # noqa: F401
    EvictionSpec, PendingApply, Replica, ReplicaCatalog, ReplicaSet,
    WriteLeaseContended, WriteLeaseSpec, WritePolicy,
)
from repro.core.lease import LeaseManager  # noqa: F401
from repro.core.tasks import (  # noqa: F401
    ConflictRecord, DeadLetter, LockTable, MaintenanceReport,
    MaintenanceScheduler, MaintenanceSpec, RetryPolicy, ScheduledTask,
)
from repro.core.faults import (  # noqa: F401
    CrashEvent, FaultInjector, FaultPlan, FlapEvent, HealEvent,
    PartitionEvent,
)
from repro.core.namespace import XufsClient, XufsFile, Mount  # noqa: F401
from repro.core.prefetch import Prefetcher  # noqa: F401
from repro.core.session import Session, UserFileServer, ussh_login  # noqa: F401
from repro.core.fabric import (  # noqa: F401
    Fabric, FabricSpec, LinkSpec, MountSpec, ReplicaPolicy, SiteSpec,
)

__all__ = [
    # declarative topology / session surface (docs/fabric.md)
    "Fabric", "FabricSpec", "SiteSpec", "LinkSpec", "ReplicaPolicy",
    "EvictionSpec", "MountSpec", "Session", "UserFileServer", "ussh_login",
    # transport
    "Network", "Endpoint", "LinkModel", "Transfer", "TransferBatch",
    "TransferRequest", "KeyPhrase",
    "DisconnectedError", "AuthError", "QuorumNotReachedError",
    "KB", "MB", "GB",
    # striping
    "plan_stripes", "reassemble", "StripePlan", "StripedTransfer",
    "TransferGroup", "STRIPE_THRESHOLD", "MIN_BLOCK", "MAX_STRIPES",
    # bulk-transfer plane (docs/transport.md)
    "BulkSpec", "BulkTransfer", "BulkResult", "grant_streams",
    "ensure_channel_width",
    # stores / cache / WAL
    "HomeStore", "ObjectStat", "CacheSpace", "CacheEntry", "CacheStats",
    "MetaOpQueue", "OpRecord",
    # coherency / replication / leases
    "NotificationManager", "PendingApply", "Replica", "ReplicaCatalog",
    "ReplicaSet", "WritePolicy", "LeaseManager",
    # concurrent-writer safety (docs/consistency.md)
    "WriteLeaseSpec", "WriteLeaseContended", "ConflictRecord",
    "vts_merge", "vts_dominates", "vts_concurrent",
    # deterministic fault injection (docs/maintenance.md)
    "FaultPlan", "FaultInjector", "PartitionEvent", "HealEvent",
    "FlapEvent", "CrashEvent",
    # background maintenance plane (docs/maintenance.md)
    "MaintenanceSpec", "MaintenanceScheduler", "MaintenanceReport",
    "RetryPolicy", "ScheduledTask", "DeadLetter", "LockTable",
    # client
    "XufsClient", "XufsFile", "Mount", "Prefetcher",
]
