"""HomeStore: the authoritative object store (the user's "home space").

Objects are versioned blobs persisted on local disk with atomic renames.
The store runs *at* an endpoint (the user's workstation in the paper; the
checkpoint authority in the training adaptation) and pushes change
notifications to registered callback channels (paper §3.1).
"""
from __future__ import annotations

import json
import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.transport import (
    Endpoint, KeyPhrase, Network, make_challenge, respond, verify, AuthError,
)


@dataclass
class ObjectStat:
    path: str
    size: int
    version: int
    mtime: float
    is_dir: bool = False

    def to_json(self) -> Dict:
        return {"path": self.path, "size": self.size, "version": self.version,
                "mtime": self.mtime, "is_dir": self.is_dir}

    @classmethod
    def from_json(cls, d: Dict) -> "ObjectStat":
        return cls(**d)


class HomeStore:
    """Versioned blob store over a local directory.

    Layout: ``<root>/data/<path>`` plus ``<root>/meta/<path>.json``.
    """

    def __init__(self, root: str, endpoint: Optional[Endpoint] = None,
                 keyphrase: Optional[KeyPhrase] = None):
        self.root = root
        self.endpoint = endpoint
        self.keyphrase = keyphrase or KeyPhrase.generate()
        os.makedirs(os.path.join(root, "data"), exist_ok=True)
        os.makedirs(os.path.join(root, "meta"), exist_ok=True)
        # path -> list of notify callables (version, stat)
        self._subscribers: List[Callable[[str, ObjectStat], None]] = []
        self._authed_tokens: set = set()
        self._locks: Dict[str, Tuple[str, float]] = {}  # path -> (owner, expiry)
        # path -> vector timestamp (writer -> logical clock): the causal
        # frontier of the bytes this store holds.  Rides existing data
        # messages, so it never costs wire traffic of its own.
        self._vts: Dict[str, Dict[str, int]] = {}

    # ---- auth (USSH <key,phrase> challenge, paper §3.2) ----------------
    def authenticate(self, respond_fn: Callable[[str], str]) -> str:
        challenge = make_challenge()
        response = respond_fn(challenge)
        if not verify(self.keyphrase, challenge, response):
            raise AuthError("challenge failed")
        token = make_challenge()
        self._authed_tokens.add(token)
        return token

    def check(self, token: str) -> None:
        if token not in self._authed_tokens:
            raise AuthError("unauthenticated session")

    # ---- paths -----------------------------------------------------------
    def _dpath(self, path: str) -> str:
        return os.path.join(self.root, "data", path.lstrip("/"))

    def _mpath(self, path: str) -> str:
        return os.path.join(self.root, "meta", path.lstrip("/") + ".json")

    # ---- object API ------------------------------------------------------
    def put(self, token: str, path: str, data: bytes,
            version: Optional[int] = None) -> ObjectStat:
        """Store a blob.  ``version=None`` bumps the local counter (the
        authoritative home path); replicas pass the home version explicitly
        so version numbers mean the same thing fabric-wide."""
        self.check(token)
        dp, mp = self._dpath(path), self._mpath(path)
        os.makedirs(os.path.dirname(dp), exist_ok=True)
        os.makedirs(os.path.dirname(mp), exist_ok=True)
        prev = self.stat_unchecked(path)
        if version is None:
            version = (prev.version + 1) if prev else 1
        # atomic write: temp + rename (crash-safe)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(dp))
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, dp)
        st = ObjectStat(path=path, size=len(data), version=version,
                        mtime=time.time())
        with open(mp + ".tmp", "w") as f:
            json.dump(st.to_json(), f)
        os.replace(mp + ".tmp", mp)
        self._notify(path, st)
        return st

    def apply_versioned(self, token: str, path: str, data: bytes,
                        version: int) -> ObjectStat:
        """Idempotent versioned apply (the quorum-write primitive).

        Writes only if ``version`` is newer than what the store holds and
        returns the stat the store ends up with either way — a flusher
        retry after a crash, or a late home reconciliation of a
        quorum-acked op, must never roll an object back to an older
        version.
        """
        self.check(token)
        cur = self.stat_unchecked(path)
        if cur is not None and cur.version >= version:
            return cur
        return self.put(token, path, data, version=version)

    def get(self, token: str, path: str) -> Tuple[bytes, ObjectStat]:
        self.check(token)
        st = self.stat_unchecked(path)
        if st is None:
            raise FileNotFoundError(path)
        with open(self._dpath(path), "rb") as f:
            return f.read(), st

    def stat(self, token: str, path: str) -> Optional[ObjectStat]:
        self.check(token)
        return self.stat_unchecked(path)

    def stat_unchecked(self, path: str) -> Optional[ObjectStat]:
        mp = self._mpath(path)
        if not os.path.exists(mp):
            return None
        with open(mp) as f:
            return ObjectStat.from_json(json.load(f))

    def vts_of(self, path: str) -> Dict[str, int]:
        """Vector timestamp of the blob at ``path`` (empty for paths
        written before vts tracking or by direct legacy puts)."""
        v = self._vts.get(path)
        return dict(v) if v else {}

    def set_vts(self, path: str, vts: Dict[str, int]) -> None:
        self._vts[path] = dict(vts)

    def delete(self, token: str, path: str) -> None:
        self.check(token)
        self._vts.pop(path, None)
        for p in (self._dpath(path), self._mpath(path)):
            if os.path.exists(p):
                os.remove(p)
        st = ObjectStat(path=path, size=0, version=-1, mtime=time.time())
        self._notify(path, st)

    def listdir(self, token: str, prefix: str) -> List[ObjectStat]:
        self.check(token)
        base = os.path.join(self.root, "meta", prefix.lstrip("/"))
        out: List[ObjectStat] = []
        if not os.path.isdir(base):
            return out
        for dirpath, _, files in os.walk(base):
            for fn in files:
                if not fn.endswith(".json"):
                    continue
                with open(os.path.join(dirpath, fn)) as f:
                    out.append(ObjectStat.from_json(json.load(f)))
        return sorted(out, key=lambda s: s.path)

    def version_vector(self, token: str, prefix: str = "") -> Dict[str, int]:
        """``path -> version`` for everything under ``prefix``.

        This is the anti-entropy primitive: a replica (or the post-crash
        sync tool) diffs its holdings against the home vector to find what
        to pull, push, or drop.
        """
        self.check(token)
        return {st.path: st.version for st in self.listdir(token, prefix)}

    # ---- locks / leases (paper §3.1 lease manager) -----------------------
    def acquire_lock(self, token: str, path: str, owner: str,
                     ttl: float, now: float) -> bool:
        self.check(token)
        cur = self._locks.get(path)
        if cur is not None and cur[1] > now and cur[0] != owner:
            return False
        self._locks[path] = (owner, now + ttl)
        return True

    def renew_lock(self, token: str, path: str, owner: str,
                   ttl: float, now: float) -> bool:
        self.check(token)
        cur = self._locks.get(path)
        if cur is None or cur[0] != owner:
            return False
        self._locks[path] = (owner, now + ttl)
        return True

    def release_lock(self, token: str, path: str, owner: str) -> None:
        self.check(token)
        cur = self._locks.get(path)
        if cur is not None and cur[0] == owner:
            del self._locks[path]

    def lock_owner(self, path: str, now: float) -> Optional[str]:
        cur = self._locks.get(path)
        if cur is None or cur[1] <= now:
            return None
        return cur[0]

    # ---- notifications -----------------------------------------------------
    def subscribe(self, cb: Callable[[str, ObjectStat], None]) -> None:
        self._subscribers.append(cb)

    def unsubscribe(self, cb: Callable[[str, ObjectStat], None]) -> None:
        if cb in self._subscribers:
            self._subscribers.remove(cb)

    def _notify(self, path: str, st: ObjectStat) -> None:
        for cb in list(self._subscribers):
            cb(path, st)
