"""CacheSpace: whole-object disk cache with hidden attribute files.

Mirrors the paper's design: ``opendir()`` recreates the remote directory in
cache space as empty entries plus hidden per-entry attribute files; only a
first ``open()`` fetches content.  Entries carry a state machine:

    EMPTY    listed, attributes cached, no data
    VALID    whole object cached, callback promise held
    DIRTY    modified locally, flush pending in the meta-op queue
    INVALID  callback fired: home changed; re-fetch before next access
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional

from repro.core.store import ObjectStat

EMPTY = "empty"
VALID = "valid"
DIRTY = "dirty"
INVALID = "invalid"


@dataclass
class CacheEntry:
    path: str
    state: str
    stat: ObjectStat

    def to_json(self) -> Dict:
        return {"path": self.path, "state": self.state,
                "stat": self.stat.to_json()}

    @classmethod
    def from_json(cls, d: Dict) -> "CacheEntry":
        return cls(path=d["path"], state=d["state"],
                   stat=ObjectStat.from_json(d["stat"]))


@dataclass(frozen=True)
class CacheStats:
    """Typed counter snapshot — what reporting consumes instead of
    poking the cache's raw dicts (``benchmarks/common.py``, eviction
    accounting)."""

    hits: int
    misses: int
    invalidations: int
    fills: int                       # total cache fills, all sources
    fills_from: Dict[str, int]       # endpoint name -> fills it served
    bytes_resident: int              # live data bytes in cache space

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class CacheSpace:
    """On-disk whole-object cache (sited on the fast local/parallel FS)."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.invalidations = 0
        # endpoint name -> number of cache fills it served (replica routing)
        self.fills_from: Dict[str, int] = {}
        # live data bytes, tracked incrementally at store/evict time
        self.bytes_resident = 0

    def record_fill(self, source: str) -> None:
        self.fills_from[source] = self.fills_from.get(source, 0) + 1

    def stats(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          invalidations=self.invalidations,
                          fills=sum(self.fills_from.values()),
                          fills_from=dict(self.fills_from),
                          bytes_resident=self.bytes_resident)

    # ---- paths: data file + hidden attr file alongside -------------------
    def data_path(self, path: str) -> str:
        return os.path.join(self.root, "obj", path.lstrip("/"))

    def attr_path(self, path: str) -> str:
        p = path.lstrip("/")
        d, name = os.path.split(p)
        return os.path.join(self.root, "obj", d, f".xufs.{name}.meta")

    # ---- entry state ------------------------------------------------------
    def lookup(self, path: str) -> Optional[CacheEntry]:
        ap = self.attr_path(path)
        if not os.path.exists(ap):
            return None
        with open(ap) as f:
            return CacheEntry.from_json(json.load(f))

    def write_entry(self, entry: CacheEntry) -> None:
        ap = self.attr_path(entry.path)
        os.makedirs(os.path.dirname(ap), exist_ok=True)
        with open(ap + ".tmp", "w") as f:
            json.dump(entry.to_json(), f)
        os.replace(ap + ".tmp", ap)

    def store_data(self, path: str, data: bytes, stat: ObjectStat,
                   state: str = VALID) -> CacheEntry:
        dp = self.data_path(path)
        os.makedirs(os.path.dirname(dp), exist_ok=True)
        old = os.path.getsize(dp) if os.path.exists(dp) else 0
        with open(dp + ".tmp", "wb") as f:
            f.write(data)
        os.replace(dp + ".tmp", dp)
        self.bytes_resident += len(data) - old
        entry = CacheEntry(path=path, state=state, stat=stat)
        self.write_entry(entry)
        return entry

    def read_data(self, path: str) -> bytes:
        with open(self.data_path(path), "rb") as f:
            return f.read()

    def populate_listing(self, stats: Iterable[ObjectStat]) -> int:
        """opendir(): create EMPTY entries + attr files (no data fetched)."""
        n = 0
        for st in stats:
            cur = self.lookup(st.path)
            if cur is not None and cur.state in (VALID, DIRTY) \
                    and cur.stat.version >= st.version:
                continue
            self.write_entry(CacheEntry(path=st.path, state=EMPTY, stat=st))
            n += 1
        return n

    def evict(self, path: str) -> int:
        """Drop the cached copy entirely: data file + hidden attr file.
        The next access is a cold fill (unlike ``invalidate``, which
        keeps the entry and marks it stale).  Returns the data bytes
        freed, so eviction accounting composes without a re-stat."""
        freed = 0
        dp = self.data_path(path)
        if os.path.exists(dp):
            freed = os.path.getsize(dp)
            os.remove(dp)
            self.bytes_resident -= freed
        ap = self.attr_path(path)
        if os.path.exists(ap):
            os.remove(ap)
        return freed

    def invalidate(self, path: str, new_stat: Optional[ObjectStat] = None):
        entry = self.lookup(path)
        if entry is None:
            return
        if entry.state == DIRTY:
            # local modifications win locally; flush order decides at home
            return
        if (new_stat is not None and entry.state == VALID
                and new_stat.version >= 0
                and new_stat.version <= entry.stat.version):
            return  # notification for the version we already hold
        entry.state = INVALID
        if new_stat is not None:
            entry.stat = new_stat
        self.write_entry(entry)
        self.invalidations += 1

    def entries(self, prefix: str = "") -> List[CacheEntry]:
        base = os.path.join(self.root, "obj", prefix.lstrip("/"))
        out: List[CacheEntry] = []
        if not os.path.isdir(base):
            return out
        for dirpath, _, files in os.walk(base):
            for fn in files:
                if fn.startswith(".xufs.") and fn.endswith(".meta"):
                    with open(os.path.join(dirpath, fn)) as f:
                        out.append(CacheEntry.from_json(json.load(f)))
        return sorted(out, key=lambda e: e.path)
