"""Striped transfers (paper §3.3): >64 KB moves across up to 12 streams.

``StripePlan`` is pure logic (tested exhaustively with hypothesis);
``StripedTransfer`` executes a plan over the simulated transport, moving
real bytes and charging the virtual clock for the *parallel* stripe time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.transport import Endpoint, Network, KB

STRIPE_THRESHOLD = 64 * KB   # transfers above this are striped
MIN_BLOCK = 64 * KB          # minimum stripe block size
MAX_STRIPES = 12             # parallel TCP connections


@dataclass(frozen=True)
class StripePlan:
    total: int
    stripes: Tuple[Tuple[int, int], ...]   # (offset, length) per stripe

    @property
    def n_streams(self) -> int:
        return len(self.stripes)


def plan_stripes(nbytes: int, max_stripes: int = MAX_STRIPES,
                 min_block: int = MIN_BLOCK,
                 threshold: int = STRIPE_THRESHOLD) -> StripePlan:
    """Split ``nbytes`` into <= max_stripes contiguous ranges of >= min_block
    (the last stripe takes the remainder).  Below threshold: single stream.
    """
    if nbytes <= threshold:
        return StripePlan(nbytes, ((0, nbytes),) if nbytes else ())
    n = min(max_stripes, max(nbytes // min_block, 1))
    base = nbytes // n
    stripes: List[Tuple[int, int]] = []
    off = 0
    for i in range(n):
        ln = base if i < n - 1 else nbytes - off
        stripes.append((off, ln))
        off += ln
    return StripePlan(nbytes, tuple(stripes))


def reassemble(plan: StripePlan, parts: List[bytes]) -> bytes:
    """Stitch stripe payloads back together (order-independent by offset)."""
    assert len(parts) == plan.n_streams
    buf = bytearray(plan.total)
    for (off, ln), part in zip(plan.stripes, parts):
        assert len(part) == ln, (len(part), ln)
        buf[off:off + ln] = part
    return bytes(buf)


@dataclass
class StripedTransfer:
    """Moves payloads between endpoints with striping + clock accounting."""

    network: Network
    max_stripes: int = MAX_STRIPES

    def send(self, src: str, dst: str, payload: bytes, *,
             encrypted: bool = False,
             max_stripes: Optional[int] = None) -> float:
        """Returns modeled elapsed seconds for the (parallel) transfer."""
        plan = plan_stripes(len(payload),
                            max_stripes=max_stripes or self.max_stripes)
        # stripes run in parallel: aggregate bandwidth = n * per-stream bw,
        # capped by the link  ->  latency + total / aggregate.
        dt = self.network.rpc(src, dst, "striped_send", len(payload),
                              n_streams=max(plan.n_streams, 1),
                              encrypted=encrypted)
        # exercise the real data path: split + reassemble must round-trip
        parts = [payload[off:off + ln] for off, ln in plan.stripes]
        out = reassemble(plan, parts)
        assert out == payload
        return dt
