"""Striped transfers (paper §3.3): >64 KB moves across up to 12 streams.

``StripePlan`` is pure logic (tested exhaustively with hypothesis);
``StripedTransfer`` executes a plan over the simulated transport: each
stripe is its own concurrent channel reservation, so the elapsed time is
the max over the stripe channels (not the sum).  ``begin()`` issues the
reservations without advancing the clock — the async primitive replica
fan-out pipelines on — while ``send()`` is the blocking wrapper.

Every stripe reservation individually charges the per-endpoint NIC
budget at both ends (``Network._charge_nic``), so striping a payload
12-wide cannot exceed the shared uplink: the stripes serialize through
the NIC at the budget rate and the group completion stretches to the
NIC backlog exactly as one aggregate transfer would.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

from repro.core.bulk import BulkSpec, ensure_channel_width, grant_streams
from repro.core.transport import (
    Endpoint, KB, Network, Transfer, TransferBatch, TransferRequest,
)

STRIPE_THRESHOLD = 64 * KB   # transfers above this are striped
MIN_BLOCK = 64 * KB          # minimum stripe block size
MAX_STRIPES = 12             # parallel TCP connections


@dataclass(frozen=True)
class StripePlan:
    total: int
    stripes: Tuple[Tuple[int, int], ...]   # (offset, length) per stripe

    @property
    def n_streams(self) -> int:
        return len(self.stripes)


def plan_stripes(nbytes: int, max_stripes: int = MAX_STRIPES,
                 min_block: int = MIN_BLOCK,
                 threshold: int = STRIPE_THRESHOLD) -> StripePlan:
    """Split ``nbytes`` into <= max_stripes contiguous ranges of >= min_block
    (the last stripe takes the remainder).  Below threshold: single stream.
    """
    if nbytes <= threshold:
        return StripePlan(nbytes, ((0, nbytes),) if nbytes else ())
    n = min(max_stripes, max(nbytes // min_block, 1))
    base = nbytes // n
    stripes: List[Tuple[int, int]] = []
    off = 0
    for i in range(n):
        ln = base if i < n - 1 else nbytes - off
        stripes.append((off, ln))
        off += ln
    return StripePlan(nbytes, tuple(stripes))


def reassemble(plan: StripePlan, parts: List[bytes]) -> bytes:
    """Stitch stripe payloads back together (order-independent by offset)."""
    assert len(parts) == plan.n_streams
    buf = bytearray(plan.total)
    for (off, ln), part in zip(plan.stripes, parts):
        assert len(part) == ln, (len(part), ln)
        buf[off:off + ln] = part
    return bytes(buf)


class TransferGroup:
    """The in-flight stripes of one logical payload, backed by ONE
    reservation batch (``Network.transfer_batch``)."""

    __slots__ = ("plan", "batch")

    def __init__(self, plan: StripePlan, batch: TransferBatch):
        self.plan = plan
        self.batch = batch

    @property
    def transfers(self) -> List[Transfer]:
        """Per-stripe records (materialized lazily from the batch)."""
        return self.batch.transfers

    @property
    def completion(self) -> float:
        """When the whole payload has landed: max over stripe channels."""
        return self.batch.completion


@dataclass
class StripedTransfer:
    """Moves payloads between endpoints with striping + clock accounting."""

    network: Network
    max_stripes: int = MAX_STRIPES
    # optional bulk policy (repro.core.bulk): when set, the plan width
    # follows the granted stream budget — BDP/NIC/payload-derived — and
    # the channel pool is raised to carry it.  None (default) keeps the
    # fixed MAX_STRIPES constant, plans and traces bit-identical; a
    # fixed-width spec (adapt=False, max_streams=12) is likewise
    # provably identical because the payload clamp mirrors
    # ``plan_stripes``' own ``nbytes // min_block`` bound.
    spec: Optional[BulkSpec] = None

    def begin(self, src: str, dst: str, payload: bytes, *,
              encrypted: bool = False, max_stripes: Optional[int] = None,
              not_before: float = 0.0) -> TransferGroup:
        """Reserve one channel per stripe; the clock does not move.

        Each stripe is a single stream holding a ``link_bw / n`` share at
        most, so for equal stripes the group completion matches the old
        aggregate n-stream model — but the stripes now occupy channels,
        letting unrelated transfers overlap with them.
        """
        if max_stripes is None and self.spec is not None:
            width = grant_streams(self.network, src, dst, len(payload),
                                  self.spec)
            ensure_channel_width(self.network, width)
        else:
            width = max_stripes or self.max_stripes
        plan = plan_stripes(len(payload), max_stripes=width)
        n = max(plan.n_streams, 1)
        reqs = [
            TransferRequest(src, dst, "stripe", ln, n, encrypted, not_before)
            for _off, ln in plan.stripes
        ] or [TransferRequest(src, dst, "stripe", 0, 1, encrypted,
                              not_before)]
        batch = self.network.transfer_batch(reqs)
        # exercise the real data path: split + reassemble must round-trip
        parts = [payload[off:off + ln] for off, ln in plan.stripes]
        assert reassemble(plan, parts) == payload
        return TransferGroup(plan, batch)

    def send(self, src: str, dst: str, payload: bytes, *,
             encrypted: bool = False,
             max_stripes: Optional[int] = None) -> float:
        """Blocking transfer; returns the modeled (parallel) elapsed
        seconds the caller observed."""
        t0 = self.network.clock
        group = self.begin(src, dst, payload, encrypted=encrypted,
                           max_stripes=max_stripes)
        self.network.wait_batch(group.batch)
        return self.network.clock - t0
