"""Persisted meta-operation queue (paper §3.1): the write-behind WAL.

Every mutating operation appends a record and returns — nothing blocks on
the WAN.  A flusher drains the queue in order to the home store; records
are marked done only after the remote op succeeds, so a crash at any point
replays safely (operations are idempotent: puts overwrite, deletes are
tolerant).  ``replay()`` is the paper's post-crash sync tool.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.core.transport import DisconnectedError

PENDING = "pending"
DONE = "done"


@dataclass
class OpRecord:
    seq: int
    op: str               # "store" | "delete" | "setattr"
    path: str
    payload_file: Optional[str] = None   # shadow-file holding the data
    status: str = PENDING

    def to_json(self) -> Dict:
        return {"seq": self.seq, "op": self.op, "path": self.path,
                "payload_file": self.payload_file, "status": self.status}

    @classmethod
    def from_json(cls, d: Dict) -> "OpRecord":
        return cls(**d)


class MetaOpQueue:
    """Append-only JSONL WAL + shadow-file directory."""

    def __init__(self, root: str, compact_threshold: int = 512):
        self.root = root
        os.makedirs(root, exist_ok=True)
        os.makedirs(os.path.join(root, "shadow"), exist_ok=True)
        self.wal_path = os.path.join(root, "oplog.jsonl")
        self.compact_threshold = compact_threshold
        self._lines_written = 0
        self._next_seq = self._recover_next_seq()

    def _recover_next_seq(self) -> int:
        last = 0
        for rec in self.scan():
            last = max(last, rec.seq)
        return last + 1

    # ---- append ----------------------------------------------------------
    def shadow_path(self, seq: int) -> str:
        return os.path.join(self.root, "shadow", f"{seq:012d}.bin")

    def append(self, op: str, path: str,
               data: Optional[bytes] = None) -> OpRecord:
        seq = self._next_seq
        self._next_seq += 1
        payload_file = None
        if data is not None:
            payload_file = self.shadow_path(seq)
            tmp = payload_file + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, payload_file)
        rec = OpRecord(seq=seq, op=op, path=path, payload_file=payload_file)
        with open(self.wal_path, "a") as f:
            f.write(json.dumps(rec.to_json()) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._lines_written += 1
        return rec

    def mark_done(self, rec: OpRecord) -> None:
        rec.status = DONE
        with open(self.wal_path, "a") as f:
            f.write(json.dumps(rec.to_json()) + "\n")
            f.flush()
        self._lines_written += 1
        if rec.payload_file and os.path.exists(rec.payload_file):
            os.remove(rec.payload_file)
        if (self._lines_written >= self.compact_threshold
                and not getattr(self, "_compacting", False)):
            self.compact()

    # ---- scan / replay -----------------------------------------------------
    def scan(self) -> List[OpRecord]:
        """Latest state per seq, ascending (truncated/garbage lines skipped)."""
        state: Dict[int, OpRecord] = {}
        if not os.path.exists(self.wal_path):
            return []
        with open(self.wal_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = OpRecord.from_json(json.loads(line))
                except (json.JSONDecodeError, TypeError):
                    continue  # torn write at crash tail
                state[rec.seq] = rec
        return [state[s] for s in sorted(state)]

    def pending(self) -> List[OpRecord]:
        # last-close-wins: only the newest pending store per path is shipped
        recs = [r for r in self.scan() if r.status == PENDING]
        newest: Dict[str, int] = {}
        for r in recs:
            if r.op == "store":
                newest[r.path] = r.seq
        out = []
        for r in recs:
            if r.op == "store" and newest.get(r.path) != r.seq:
                # superseded by a later close; mark done without shipping
                self.mark_done(r)
                continue
            out.append(r)
        return out

    def flush(self, apply_fn: Callable[[OpRecord, Optional[bytes]], None],
              max_ops: Optional[int] = None) -> int:
        """Drain pending ops through ``apply_fn`` (raises stop the drain).

        Returns the number of ops successfully applied.
        """
        done = 0
        for rec in self.pending():
            data = None
            if rec.payload_file:
                if not os.path.exists(rec.payload_file):
                    self.mark_done(rec)   # shadow lost after done-crash race
                    continue
                with open(rec.payload_file, "rb") as f:
                    data = f.read()
            try:
                apply_fn(rec, data)
            except DisconnectedError:
                break   # WAN down: keep queueing (disconnected operation)
            self.mark_done(rec)
            done += 1
            if max_ops is not None and done >= max_ops:
                break
        return done

    def replay(self, apply_fn: Callable[[OpRecord, Optional[bytes]], None],
               ) -> int:
        """Post-crash convergence: re-drain every record still pending.

        A record is pending until ``apply_fn`` ran to completion — a crash
        *between* the authoritative apply and any secondary effect (e.g.
        the replica fan-out) therefore re-applies the whole record.  Safe
        because stores overwrite and deletes are tolerant.
        """
        return self.flush(apply_fn)

    def compact(self) -> None:
        """Rewrite the WAL keeping only pending records."""
        self._compacting = True
        try:
            recs = self.pending()
            tmp = self.wal_path + ".tmp"
            with open(tmp, "w") as f:
                for rec in recs:
                    f.write(json.dumps(rec.to_json()) + "\n")
            os.replace(tmp, self.wal_path)
            self._lines_written = len(recs)
        finally:
            self._compacting = False
