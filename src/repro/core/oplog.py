"""Persisted meta-operation queue (paper §3.1): the write-behind WAL.

Every mutating operation appends a record and returns — nothing blocks on
the WAN.  A flusher drains the queue in order to the write group (home +
replicas); per-endpoint acknowledgements are persisted as they arrive, so
a flusher crash mid-quorum resumes exactly where it left off.  A record
moves through four states:

  ``pending``       appended, no endpoint has confirmed the apply;
  ``applied@home``  the authoritative home confirmed, but fewer than W of
                    the N write endpoints have — the flusher keeps pushing;
  ``quorum``        at least W endpoints confirmed but home is NOT among
                    them (home was partitioned): the op is client-complete
                    — the client's ``sync()`` no longer waits on it — yet
                    the record (and its shadow payload) is retained until
                    ``reconcile()`` lands the apply at home;
  ``done``          home confirmed and the quorum was met: the record is
                    retired and its shadow file dropped.  Replicas beyond
                    the quorum converge via anti-entropy, not the WAL.

``replay()`` is the paper's post-crash sync tool; ``reconcile()`` is the
quorum-era addition that re-drives home applies for quorum-acked ops once
the home partition heals.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.transport import DisconnectedError

PENDING = "pending"
APPLIED_HOME = "applied@home"
QUORUM = "quorum"
DONE = "done"

#: Statuses the flusher still has to push (the op is not client-complete).
FLUSHABLE = (PENDING, APPLIED_HOME)


# ---- vector-timestamp algebra ------------------------------------------
# A vts maps writer name -> logical clock.  It rides OpRecord and the
# stores' per-path frontier so concurrent branches written around a dead
# home are detectable at reconcile time instead of silently clobbering.

def vts_merge(a: Optional[Dict[str, int]],
              b: Optional[Dict[str, int]]) -> Dict[str, int]:
    """Pointwise max — the least upper bound of two causal histories."""
    out = dict(a) if a else {}
    if b:
        for k, v in b.items():
            if v > out.get(k, 0):
                out[k] = v
    return out


def vts_dominates(a: Optional[Dict[str, int]],
                  b: Optional[Dict[str, int]]) -> bool:
    """True when ``a``'s history includes all of ``b``'s (``a >= b``
    pointwise; equality dominates).  Everything dominates the empty
    (pre-vts / legacy) stamp."""
    if not b:
        return True
    if not a:
        return False
    return all(a.get(k, 0) >= v for k, v in b.items())


def vts_concurrent(a: Optional[Dict[str, int]],
                   b: Optional[Dict[str, int]]) -> bool:
    """Neither branch knows about the other — a true conflict."""
    return not vts_dominates(a, b) and not vts_dominates(b, a)


def vts_lww_key(vts: Optional[Dict[str, int]]) -> Tuple:
    """Deterministic total order for last-writer-wins tie-breaking of
    concurrent branches: more total causal events wins, then the
    lexicographically greatest sorted (writer, clock) sequence.  Two
    concurrent branches can never compare equal (equal sums + equal
    sorted items would be the same dict)."""
    v = vts or {}
    return (sum(v.values()), tuple(sorted(v.items())))


@dataclass
class OpRecord:
    seq: int
    op: str               # "store" | "delete" | "setattr"
    path: str
    payload_file: Optional[str] = None   # shadow-file holding the data
    status: str = PENDING
    acked: List[str] = field(default_factory=list)  # endpoints that confirmed
    version: Optional[int] = None        # version pinned at first apply
    #: vector timestamp stamped at first apply (None on legacy records:
    #: reconcile then keeps the historical blind put-on-top behavior)
    vts: Optional[Dict[str, int]] = None

    def to_json(self) -> Dict:
        return {"seq": self.seq, "op": self.op, "path": self.path,
                "payload_file": self.payload_file, "status": self.status,
                "acked": self.acked, "version": self.version,
                "vts": self.vts}

    @classmethod
    def from_json(cls, d: Dict) -> "OpRecord":
        return cls(**d)


class MetaOpQueue:
    """Append-only JSONL WAL + shadow-file directory."""

    def __init__(self, root: str, compact_threshold: int = 512):
        self.root = root
        os.makedirs(root, exist_ok=True)
        os.makedirs(os.path.join(root, "shadow"), exist_ok=True)
        self.wal_path = os.path.join(root, "oplog.jsonl")
        self.compact_threshold = compact_threshold
        self._lines_written = 0
        self._next_seq = self._recover_next_seq()

    def _recover_next_seq(self) -> int:
        last = 0
        for rec in self.scan():
            last = max(last, rec.seq)
        return last + 1

    # ---- append ----------------------------------------------------------
    def shadow_path(self, seq: int) -> str:
        return os.path.join(self.root, "shadow", f"{seq:012d}.bin")

    def append(self, op: str, path: str,
               data: Optional[bytes] = None) -> OpRecord:
        seq = self._next_seq
        self._next_seq += 1
        payload_file = None
        if data is not None:
            payload_file = self.shadow_path(seq)
            tmp = payload_file + ".tmp"
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, payload_file)
        rec = OpRecord(seq=seq, op=op, path=path, payload_file=payload_file)
        with open(self.wal_path, "a") as f:
            f.write(json.dumps(rec.to_json()) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._lines_written += 1
        return rec

    def _persist(self, rec: OpRecord) -> None:
        with open(self.wal_path, "a") as f:
            f.write(json.dumps(rec.to_json()) + "\n")
            f.flush()
        self._lines_written += 1

    # ---- ack bookkeeping -------------------------------------------------
    def mark_acked(self, rec: OpRecord, endpoint: str,
                   version: Optional[int] = None,
                   home: bool = False) -> None:
        """Persist one endpoint's apply confirmation.

        Written to the WAL *before* the flusher moves to the next
        endpoint, so a crash after W-1 acks resumes with those acks in
        hand instead of re-earning them.
        """
        if endpoint not in rec.acked:
            rec.acked.append(endpoint)
        if version is not None:
            rec.version = version
        if home and rec.status == PENDING:
            rec.status = APPLIED_HOME
        self._persist(rec)

    def mark_quorum(self, rec: OpRecord) -> None:
        """W acks reached without home: client-complete, home outstanding."""
        rec.status = QUORUM
        self._persist(rec)

    def mark_done(self, rec: OpRecord) -> None:
        rec.status = DONE
        self._persist(rec)
        if rec.payload_file and os.path.exists(rec.payload_file):
            os.remove(rec.payload_file)
        if (self._lines_written >= self.compact_threshold
                and not getattr(self, "_compacting", False)):
            self.compact()

    # ---- scan / replay -----------------------------------------------------
    def scan(self) -> List[OpRecord]:
        """Latest state per seq, ascending (truncated/garbage lines skipped)."""
        state: Dict[int, OpRecord] = {}
        if not os.path.exists(self.wal_path):
            return []
        with open(self.wal_path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = OpRecord.from_json(json.loads(line))
                except (json.JSONDecodeError, TypeError):
                    continue  # torn write at crash tail
                state[rec.seq] = rec
        return [state[s] for s in sorted(state)]

    def pending(self) -> List[OpRecord]:
        # last-close-wins: only the newest pending store per path is shipped
        recs = [r for r in self.scan() if r.status in FLUSHABLE]
        newest: Dict[str, int] = {}
        for r in recs:
            if r.op == "store":
                newest[r.path] = r.seq
        out = []
        for r in recs:
            if r.op == "store" and newest.get(r.path) != r.seq:
                # superseded by a later close; mark done without shipping
                self.mark_done(r)
                continue
            out.append(r)
        return out

    def unreconciled(self) -> List[OpRecord]:
        """Quorum-acked ops whose authoritative home apply is outstanding."""
        return [r for r in self.scan() if r.status == QUORUM]

    def retire_superseded(self, path: str, before_seq: int) -> int:
        """Retire quorum-parked stores of ``path`` older than an op that
        just completed — reconciling such a store later would resurrect
        deleted/overwritten data (last-close-wins applies to parked
        records too)."""
        n = 0
        for rec in self.unreconciled():
            if rec.path == path and rec.seq < before_seq:
                self.mark_done(rec)
                n += 1
        return n

    def _read_payload(self, rec: OpRecord) -> Optional[bytes]:
        if not rec.payload_file:
            return None
        if not os.path.exists(rec.payload_file):
            return None
        with open(rec.payload_file, "rb") as f:
            return f.read()

    def flush(self, apply_fn: Callable[[OpRecord, Optional[bytes]], Optional[bool]],
              max_ops: Optional[int] = None) -> int:
        """Drain flushable ops in order through ``apply_fn``.

        ``apply_fn`` returns truthy (or ``None``, the single-endpoint
        legacy contract) when the authoritative home acknowledged — the
        record retires to ``done`` — and ``False`` when a W-of-N quorum
        acked around a partitioned home: the record parks at ``quorum``
        for later :meth:`reconcile`.  :class:`DisconnectedError` (which a
        missed quorum subclasses) stops the drain; partial acks stay
        persisted.  Returns the number of client-complete ops.
        """
        done = 0
        parked_paths = {r.path for r in self.unreconciled()}
        for rec in self.pending():
            data = None
            if rec.payload_file:
                data = self._read_payload(rec)
                if data is None:
                    self.mark_done(rec)   # shadow lost after done-crash race
                    continue
            try:
                home_acked = apply_fn(rec, data)
            except DisconnectedError:
                break   # WAN down: keep queueing (disconnected operation)
            if home_acked is None or home_acked:
                self.mark_done(rec)
            else:
                self.mark_quorum(rec)
            if rec.op == "store" and rec.path in parked_paths:
                # a newer close completed: older parked stores of this
                # path must never reconcile over it
                self.retire_superseded(rec.path, rec.seq)
            done += 1
            if max_ops is not None and done >= max_ops:
                break
        return done

    def replay(self, apply_fn: Callable[[OpRecord, Optional[bytes]],
                                        Optional[bool]]) -> int:
        """Post-crash convergence: re-drain every record still flushable.

        A record is flushable until its quorum was met — a crash *between*
        two endpoint acks resumes from the persisted ack set, skipping
        endpoints that already confirmed.  Safe because versioned applies
        are idempotent (stores overwrite same-or-older versions only,
        deletes are tolerant).
        """
        return self.flush(apply_fn)

    def reconcile(self, apply_fn: Callable[[OpRecord, Optional[bytes]],
                                           Optional[bool]]) -> int:
        """Land the home apply for quorum-parked ops (home healed).

        Each record that ``apply_fn`` now reports home-acked retires to
        ``done``; records whose home is still unreachable stay parked.
        Returns the number of records retired.
        """
        retired = 0
        for rec in self.unreconciled():
            data = self._read_payload(rec)
            if rec.payload_file and data is None:
                self.mark_done(rec)       # shadow lost after done-crash race
                continue
            try:
                home_acked = apply_fn(rec, data)
            except DisconnectedError:
                continue                  # home still down: stay parked
            if home_acked is None or home_acked:
                self.mark_done(rec)
                retired += 1
        return retired

    def compact(self) -> None:
        """Rewrite the WAL keeping only live (flushable/quorum) records."""
        self._compacting = True
        try:
            recs = sorted(self.pending() + self.unreconciled(),
                          key=lambda r: r.seq)
            tmp = self.wal_path + ".tmp"
            with open(tmp, "w") as f:
                for rec in recs:
                    f.write(json.dumps(rec.to_json()) + "\n")
            os.replace(tmp, self.wal_path)
            self._lines_written = len(recs)
        finally:
            self._compacting = False
