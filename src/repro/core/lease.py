"""Lease manager (paper §3.1): remote locks with TTL renewal.

Locks on XUFS-mounted paths are forwarded to the file server; the lease
manager renews them periodically so a crashed client's locks expire rather
than orphan.  Files in *localized directories* use cache-space-local locks
(the parallel FS's own locking in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Set

from repro.core.store import HomeStore
from repro.core.transport import DisconnectedError, Network

DEFAULT_TTL = 30.0


@dataclass
class LeaseManager:
    network: Network
    client_name: str
    server_name: str
    store: HomeStore
    owner: str
    token: str = ""
    ttl: float = DEFAULT_TTL
    held: Set[str] = field(default_factory=set)
    local_locks: Set[str] = field(default_factory=set)

    def acquire(self, path: str, localized: bool = False) -> bool:
        if localized:
            if path in self.local_locks:
                return True
            self.local_locks.add(path)
            return True
        self.network.rpc(self.client_name, self.server_name, "lock_acquire")
        ok = self.store.acquire_lock(self.token, path, self.owner, self.ttl,
                                     self.network.clock)
        if ok:
            self.held.add(path)
        return ok

    def release(self, path: str) -> None:
        if path in self.local_locks:
            self.local_locks.discard(path)
            return
        if path in self.held:
            try:
                self.network.rpc(self.client_name, self.server_name,
                                 "lock_release")
                self.store.release_lock(self.token, path, self.owner)
            except DisconnectedError:
                pass   # lease will expire server-side
            self.held.discard(path)

    def renew_all(self) -> int:
        """Periodic renewal; drops leases the server no longer honors.

        Renewals are independent round-trips, so they ride the channel
        pool concurrently — one RTT per ``channels_per_pair`` leases, not
        one per lease.
        """
        renewed = 0
        probes = []
        for path in list(self.held):
            try:
                probes.append((path, self.network.transfer(
                    self.client_name, self.server_name, "lock_renew")))
            except DisconnectedError:
                break            # WAN down: only the issued renewals count
        self.network.wait_all([t for _, t in probes])
        for path, _t in probes:
            if self.store.renew_lock(self.token, path, self.owner, self.ttl,
                                     self.network.clock):
                renewed += 1
            else:
                self.held.discard(path)
        return renewed
