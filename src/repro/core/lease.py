"""Lease manager (paper §3.1): remote locks with TTL renewal.

Locks on XUFS-mounted paths are forwarded to the file server; the lease
manager renews them periodically so a crashed client's locks expire rather
than orphan.  Files in *localized directories* use cache-space-local locks
(the parallel FS's own locking in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Set, Tuple

from repro.core.store import HomeStore
from repro.core.transport import DisconnectedError, Network

DEFAULT_TTL = 30.0


@dataclass
class LeaseManager:
    network: Network
    client_name: str
    server_name: str
    store: HomeStore
    owner: str
    token: str = ""
    ttl: float = DEFAULT_TTL
    held: Set[str] = field(default_factory=set)
    local_locks: Set[str] = field(default_factory=set)
    #: Leases we hold but could not confirm with the server (a partition
    #: interrupted renewal, or a re-mount rotated the token): the
    #: server-side TTL keeps running, so these must be re-verified (or
    #: dropped) before the client may keep acting as lock holder.
    at_risk: Set[str] = field(default_factory=set)
    #: Releases a partition interrupted: we no longer act as holder, but
    #: the server still does — its TTL keeps other writers locked out
    #: until it lapses.  ``reverify_at_risk`` finishes these releases on
    #: heal instead of waiting out the TTL.
    pending_release: Set[str] = field(default_factory=set)
    renew_interruptions: int = 0

    def acquire(self, path: str, localized: bool = False) -> bool:
        if localized:
            if path in self.local_locks:
                return True
            self.local_locks.add(path)
            return True
        self.network.rpc(self.client_name, self.server_name, "lock_acquire")
        ok = self.store.acquire_lock(self.token, path, self.owner, self.ttl,
                                     self.network.clock)
        if ok:
            self.held.add(path)
        return ok

    def release(self, path: str) -> None:
        if path in self.local_locks:
            self.local_locks.discard(path)
            return
        if path in self.held:
            try:
                self.network.rpc(self.client_name, self.server_name,
                                 "lock_release")
                self.store.release_lock(self.token, path, self.owner)
                self.at_risk.discard(path)
            except DisconnectedError:
                # The server still holds the lock and its TTL keeps
                # running, blocking other writers until it lapses.
                # Remember the intent (mirror of the renew_all at-risk
                # fix) so the release completes on heal instead of the
                # lease silently vanishing from our books while the
                # server honors it.
                self.pending_release.add(path)
                self.at_risk.add(path)
            self.held.discard(path)

    def renew_all(self) -> int:
        """Periodic renewal; drops leases the server no longer honors.

        Renewals are independent round-trips, so they ride the channel
        pool concurrently — one RTT per ``channels_per_pair`` leases, not
        one per lease.

        A partition mid-renewal leaves every not-yet-probed lease
        **at risk**: the server-side TTL keeps running while we cannot
        reach it, so those paths move to ``at_risk`` instead of silently
        staying in ``held`` as if renewed (the old behavior — the client
        kept acting as lock holder after the server expired the lease).
        :meth:`reverify_at_risk` settles them once the link heals.
        """
        renewed = 0
        probes = []
        paths = sorted(self.held)        # deterministic probe order
        cut = len(paths)
        for i, path in enumerate(paths):
            try:
                probes.append((path, self.network.transfer(
                    self.client_name, self.server_name, "lock_renew")))
            except DisconnectedError:
                cut = i          # WAN down: the remainder was never probed
                self.renew_interruptions += 1
                break
        self.network.wait_all([t for _, t in probes])
        for path, _t in probes:
            if self.store.renew_lock(self.token, path, self.owner, self.ttl,
                                     self.network.clock):
                renewed += 1
                self.at_risk.discard(path)
            else:
                self.held.discard(path)
                self.at_risk.discard(path)
        for path in paths[cut:]:
            if path in self.held:
                self.at_risk.add(path)
        return renewed

    def reverify_at_risk(self) -> Tuple[int, int]:
        """Settle leases left at risk by an interrupted renewal.

        Re-probes the server for each at-risk path: a lease it still
        honors is renewed and kept; one it expired (or re-granted to
        another owner) is dropped from ``held`` — holding a lock on hope
        alone is exactly the corruption the at-risk set exists to stop.
        Called from ``XufsClient.reconnect()`` and the scheduled lease
        task.  Returns ``(kept, dropped)``; if the WAN is still down,
        everything left unprobed stays at risk.
        """
        kept = dropped = 0
        probes = []
        for path in sorted(self.at_risk):
            op = ("lock_release" if path in self.pending_release
                  else "lock_reverify")
            try:
                probes.append((path, self.network.transfer(
                    self.client_name, self.server_name, op)))
            except DisconnectedError:
                break            # still partitioned: the rest stay at risk
        self.network.wait_all([t for _, t in probes])
        for path, _t in probes:
            if path in self.pending_release:
                # finish the interrupted release: the server-side lock
                # goes away now instead of at TTL expiry
                self.store.release_lock(self.token, path, self.owner)
                self.pending_release.discard(path)
                self.at_risk.discard(path)
                dropped += 1
                continue
            if self.store.renew_lock(self.token, path, self.owner, self.ttl,
                                     self.network.clock):
                kept += 1
            else:
                self.held.discard(path)
                dropped += 1
            self.at_risk.discard(path)
        return kept, dropped
