"""Declarative fabric spec: one topology API for sites, links, replicas,
and multi-user sessions.

XUFS's value proposition (paper §3) is that a researcher declares *what*
their private distributed namespace looks like and the system handles the
wide-area plumbing.  This module is that declaration:

  * :class:`FabricSpec` — a frozen, shareable description of a topology:
    the sites (endpoints, optionally with filesystem roots and NIC
    budgets), the links between them (latency override or a full
    :class:`LinkModel`), and the default link every undeclared pair
    rides.  Specs validate at construction, so a typo'd replica name or
    a negative budget fails before any wire is modeled.
  * :class:`Fabric` — the runtime built from a spec.  It owns the
    :class:`Network`, registers every endpoint, applies links and NIC
    budgets exactly once, and hands out sessions via :meth:`Fabric.login`
    — so multiple users/sessions compose on one shared topology as
    first-class API instead of each call site hand-rolling endpoints and
    links (which is what ``ussh_login`` used to force on every caller).
  * :class:`ReplicaPolicy` / :class:`MountSpec` — per-session policy
    (which declared sites replicate a home space, the W-of-N write-ack
    rule, queue-aware routing, an optional :class:`EvictionSpec`
    capacity bound driving on-demand placement and scheduled
    eviction) and the
    namespace mounts, separated from the topology they run on — replica
    *policy* apart from transport *mechanism*, per the GridFTP replica
    management line.

Latency composition: a replica site is near the compute site but
WAN-far from home, so when a login places a replica whose ``home <->
replica`` link was never declared, the fabric resolves it to

    default link latency  +  declared site <-> replica latency

(the rule ``ussh_login`` used to hide in its body).  Declaring an
explicit :class:`LinkSpec` for the pair overrides the composition.

``ussh_login`` (``repro.core.session``) survives as a thin deprecated
shim that assembles a :class:`FabricSpec` from its keyword arguments and
delegates here — bit-identical wiring, one ``DeprecationWarning``.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.bulk import BulkSpec
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.namespace import XufsClient
from repro.core.replication import (
    EvictionSpec, ReplicaSet, WriteLeaseSpec, WritePolicy,
)
from repro.core.session import Session, UserFileServer, _authenticate
from repro.core.store import HomeStore
from repro.core.tasks import (
    MaintenanceReport, MaintenanceScheduler, MaintenanceSpec,
)
from repro.core.transport import (
    DisconnectedError, Endpoint, KeyPhrase, LinkModel, Network,
)


def _pair(a: str, b: str) -> Tuple[str, str]:
    return (min(a, b), max(a, b))


_CAPACITY_DEPRECATION_WARNED = False


def _warn_capacity_bytes_once() -> None:
    """One DeprecationWarning per process, the ``ussh_login`` shim
    pattern: loud enough to migrate, quiet enough for a long session."""
    global _CAPACITY_DEPRECATION_WARNED
    if not _CAPACITY_DEPRECATION_WARNED:
        _CAPACITY_DEPRECATION_WARNED = True
        warnings.warn(
            "ReplicaPolicy(capacity_bytes=...) is deprecated; pass "
            "eviction=EvictionSpec(capacity=...) — the alias assembles "
            "the default spec (lru, 0.9/0.6 watermarks, 10s scans) and "
            "will be dropped in a major version; see docs/fabric.md "
            "migration table", DeprecationWarning, stacklevel=3)


@dataclass(frozen=True)
class SiteSpec:
    """One named endpoint of the fabric.

    ``root`` is the local filesystem directory backing stores/caches at
    the site (required on sites that host a home space or a client;
    replica sites store under the home site's root).  ``nic_budget``
    caps the endpoint's aggregate NIC bytes/s (``None`` = uncapped, the
    default — see ``docs/transport.md``).
    """

    name: str
    root: Optional[str] = None
    nic_budget: Optional[float] = None

    def __post_init__(self) -> None:
        if not self.name:
            raise ValueError("SiteSpec needs a non-empty name")
        if self.nic_budget is not None and self.nic_budget <= 0:
            raise ValueError(
                f"site {self.name!r}: NIC budget must be > 0, "
                f"got {self.nic_budget}")


@dataclass(frozen=True)
class LinkSpec:
    """One declared pair link: a latency override of the fabric default,
    or a full :class:`LinkModel` replacement (exactly one of the two)."""

    a: str
    b: str
    latency_s: Optional[float] = None
    link: Optional[LinkModel] = None

    def __post_init__(self) -> None:
        if self.a == self.b:
            raise ValueError(f"link {self.a!r} <-> itself is meaningless")
        if (self.latency_s is None) == (self.link is None):
            raise ValueError(
                f"link {self.a!r}<->{self.b!r}: give exactly one of "
                "latency_s or link")
        if self.latency_s is not None and self.latency_s < 0:
            raise ValueError(
                f"link {self.a!r}<->{self.b!r}: latency must be >= 0")

    @property
    def pair(self) -> Tuple[str, str]:
        return _pair(self.a, self.b)


@dataclass(frozen=True)
class ReplicaPolicy:
    """Replica *policy* for one session's home space, apart from the
    topology mechanism it runs on.

    ``sites`` names declared fabric sites that hold read replicas;
    ``write_quorum`` is the W-of-N ack rule (explicit W, ``"majority"``,
    or ``"all"`` — see ``docs/consistency.md``); ``queue_aware`` toggles
    estimated-completion routing.  ``eviction`` is an optional
    :class:`EvictionSpec` bounding each replica's resident bytes: the
    set fills on demand (read repair IS placement), resync refreshes
    only the resident hot set, and — when the fabric's maintenance
    plane is armed — a scheduled ``evict:`` task trims back under the
    watermarks (``docs/maintenance.md``).  Unset ⇒ replicas mirror the
    whole home space, traces bit-identical to the pre-eviction fabric.

    ``write_lease`` is an optional :class:`WriteLeaseSpec`: when set,
    the flusher serializes concurrent writers of one path through
    short-TTL write leases on the replica set before quorum fan-out
    (``docs/consistency.md``).  Unset (default) ⇒ no lease traffic,
    traces bit-identical to the pre-lease fabric; concurrent branches
    written around a dead home are still caught at reconcile time by
    their vector timestamps.

    ``bulk`` is an optional :class:`BulkSpec` (``repro.core.bulk``,
    ``docs/transport.md``): apply/fetch stripe widths follow the granted
    stream budget, and with ``third_party=True`` maintenance repairs
    pull from the cheapest fresh replica instead of home or the client.
    Unset ⇒ the session inherits ``FabricSpec.bulk``; both unset ⇒
    fixed-width striping, legacy sources, traces bit-identical.

    ``capacity_bytes`` survives as a deprecated alias that assembles
    ``EvictionSpec(capacity=...)`` and warns once per process (the
    ``ussh_login`` shim pattern).
    """

    sites: Tuple[str, ...] = ()
    write_quorum: WritePolicy = 1
    queue_aware: bool = True
    capacity_bytes: Optional[int] = None
    eviction: Optional[EvictionSpec] = None
    write_lease: Optional[WriteLeaseSpec] = None
    bulk: Optional[BulkSpec] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "sites", tuple(self.sites))
        if len(set(self.sites)) != len(self.sites):
            raise ValueError(f"duplicate replica sites: {self.sites}")
        if isinstance(self.write_quorum, str):
            if self.write_quorum not in ("majority", "all"):
                raise ValueError(
                    f"write_quorum must be an int, 'majority' or 'all': "
                    f"{self.write_quorum!r}")
        elif int(self.write_quorum) < 1:
            raise ValueError(f"write_quorum must be >= 1: "
                             f"{self.write_quorum}")
        if self.capacity_bytes is not None:
            if self.capacity_bytes <= 0:
                raise ValueError(
                    f"capacity_bytes must be > 0 (or None = unbounded): "
                    f"{self.capacity_bytes}")
            if self.eviction is not None:
                if self.eviction.capacity != self.capacity_bytes:
                    raise ValueError(
                        f"conflicting capacity_bytes={self.capacity_bytes} "
                        f"and eviction.capacity={self.eviction.capacity}; "
                        "drop the deprecated alias")
            else:
                _warn_capacity_bytes_once()
                object.__setattr__(
                    self, "eviction",
                    EvictionSpec(capacity=self.capacity_bytes))


@dataclass(frozen=True)
class MountSpec:
    """One namespace mount: a prefix plus its *localized* sub-prefixes —
    directories whose new data never ships back to home (paper §3.1)."""

    prefix: str
    localized: Tuple[str, ...] = ()

    def __post_init__(self) -> None:
        object.__setattr__(self, "localized", tuple(self.localized))
        if not self.prefix.endswith("/"):
            raise ValueError(
                f"mount prefix must end with '/': {self.prefix!r}")
        for sub in self.localized:
            if not sub.startswith(self.prefix):
                raise ValueError(
                    f"localized {sub!r} is not under mount {self.prefix!r}")


@dataclass(frozen=True)
class FabricSpec:
    """A declarative, shareable topology: sites, links, and the default
    :class:`LinkModel` every undeclared pair rides."""

    sites: Tuple[SiteSpec, ...] = ()
    links: Tuple[LinkSpec, ...] = ()
    link: LinkModel = field(default_factory=LinkModel)
    #: Background maintenance plane (``docs/maintenance.md``): when set,
    #: the Fabric owns ONE MaintenanceScheduler shared by all logins and
    #: every login/attach registers its resync / read-repair drain /
    #: lease-renewal / oplog-reconcile tasks on it.  Unset (default) ⇒
    #: no scheduler exists and every wire event is bit-identical to the
    #: pre-maintenance fabric.
    maintenance: Optional[MaintenanceSpec] = None
    #: Fabric-wide default bulk-transfer policy: a login whose
    #: ``ReplicaPolicy.bulk`` is unset inherits this.  Both unset
    #: (default) ⇒ no bulk plane, traces bit-identical.
    bulk: Optional[BulkSpec] = None

    def __post_init__(self) -> None:
        object.__setattr__(self, "sites", tuple(self.sites))
        object.__setattr__(self, "links", tuple(self.links))
        names = [s.name for s in self.sites]
        if len(set(names)) != len(names):
            dupes = sorted({n for n in names if names.count(n) > 1})
            raise ValueError(f"duplicate site names: {dupes}")
        known = set(names)
        pairs = set()
        for ls in self.links:
            for end in (ls.a, ls.b):
                if end not in known:
                    raise ValueError(
                        f"link {ls.a!r}<->{ls.b!r} references undeclared "
                        f"site {end!r}")
            if ls.pair in pairs:
                raise ValueError(f"duplicate link {ls.a!r}<->{ls.b!r}")
            pairs.add(ls.pair)

    def site(self, name: str) -> SiteSpec:
        for s in self.sites:
            if s.name == name:
                return s
        raise KeyError(f"{name!r} is not a declared fabric site "
                       f"(have: {sorted(s.name for s in self.sites)})")

    @classmethod
    def star(cls, home_root: Optional[str], site_root: Optional[str], *,
             home: str = "home", site: str = "site",
             replica_latencies: Optional[Dict[str, float]] = None,
             nic_budgets: Optional[Dict[str, float]] = None,
             link: Optional[LinkModel] = None,
             extra_sites: Sequence[SiteSpec] = (),
             extra_links: Sequence[LinkSpec] = ()) -> "FabricSpec":
        """The canonical one-home/one-compute-site star.

        Replica sites hang off the compute ``site`` at their declared
        latencies (the ``home <-> replica`` path is left to the
        composition rule), NIC budgets land on their named sites (a
        budget naming an endpoint outside the star becomes a
        budget-only site), and ``extra_sites`` / ``extra_links`` graft
        incast clients and the like on.  The ``ussh_login`` shim and
        the benchmarks both build their topologies through here.
        """
        budgets = dict(nic_budgets or {})
        sites = [SiteSpec(home, root=home_root,
                          nic_budget=budgets.pop(home, None)),
                 SiteSpec(site, root=site_root,
                          nic_budget=budgets.pop(site, None))]
        links = []
        for rname, latency_s in (replica_latencies or {}).items():
            sites.append(SiteSpec(rname,
                                  nic_budget=budgets.pop(rname, None)))
            links.append(LinkSpec(site, rname, latency_s=latency_s))
        for es in extra_sites:
            if es.name in budgets:        # budget named a grafted site
                es = _dc_replace(es, nic_budget=budgets.pop(es.name))
            sites.append(es)
        sites.extend(SiteSpec(name, nic_budget=b)
                     for name, b in budgets.items())
        links.extend(extra_links)
        return cls(sites=tuple(sites), links=tuple(links),
                   link=link if link is not None else LinkModel())


class Fabric:
    """Runtime topology built from a :class:`FabricSpec`.

    Owns the :class:`Network` (or attaches to an existing one — the
    ``ussh_login`` shim path), registers every declared site exactly
    once, applies link overrides and NIC budgets, and mints sessions via
    :meth:`login` / extra readers via :meth:`attach`.  All sessions share
    the one network, so their traffic contends for the same channels and
    NIC budgets — multi-user composition is the default, not a
    copy-paste exercise.
    """

    def __init__(self, spec: FabricSpec,
                 network: Optional[Network] = None):
        self.spec = spec
        if network is not None and network.link != spec.link:
            # undeclared pairs ride network.link, not spec.link — a
            # silently-divergent default would skew every derived
            # timing number
            raise ValueError(
                "FabricSpec.link differs from the attached Network's "
                "default link; declare the same LinkModel (or omit "
                "network= and let the Fabric own one)")
        self.network = network if network is not None \
            else Network(link=_dc_replace(spec.link))
        self.sessions: List[Session] = []
        #: ONE scheduler per fabric, shared by every login/attach — the
        #: per-path lock table and the counters span all sessions, which
        #: is what makes "two sessions never double-repair a path" a
        #: fabric-level guarantee rather than a per-client hope.
        self.scheduler: Optional[MaintenanceScheduler] = None
        if spec.maintenance is not None:
            self.scheduler = MaintenanceScheduler(self.network,
                                                  spec.maintenance)
        #: armed FaultInjector (:meth:`arm_faults`); None ⇒ no fault
        #: plan, every wire event bit-identical to the unarmed fabric
        self.faults: Optional[FaultInjector] = None
        # intern every declared site (and all site pairs) up front so
        # the engine's id tables and channel arrays are sized before
        # the first reservation — steady-state traffic never grows them
        self.network.prealloc([site.name for site in spec.sites])
        for site in spec.sites:
            Endpoint(site.name, self.network)
            if site.nic_budget is not None:
                self.network.set_nic_budget(site.name, site.nic_budget)
        for ls in spec.links:
            if network is not None and self.network.has_link(ls.a, ls.b):
                # attached to a live shared network: a pair another
                # fabric (or an earlier login's composition) already
                # timed is never retimed — same first-wins rule the
                # login composition follows
                continue
            self.network.set_link(ls.a, ls.b, self._resolve_link(ls))

    def _resolve_link(self, ls: LinkSpec) -> LinkModel:
        if ls.link is not None:
            return ls.link
        return _dc_replace(self.network.link, latency_s=ls.latency_s)

    def _site_root(self, name: str, override: Optional[str],
                   what: str) -> str:
        site = self.spec.site(name)        # KeyError on a typo'd name,
        #                                    override or not
        root = override if override is not None else site.root
        if root is None:
            raise ValueError(
                f"site {name!r} declares no filesystem root; a {what} "
                "needs one (SiteSpec(root=...) or the login override)")
        return root

    # ---- fault injection -------------------------------------------------
    def arm_faults(self, plan: FaultPlan) -> FaultInjector:
        """Arm a declarative :class:`FaultPlan` on this fabric's clock.

        The network pumps the injector lazily before every partition-
        sensitive operation, and the maintenance scheduler (when one is
        declared) walks its clock through fault times — so partitions,
        heals, flaps, and crashes fire exactly at their declared
        instants with no hand-rolled ``network.partition(...)``
        choreography at the call sites.  Arming an empty plan changes
        no trace; re-arming replaces the prior plan.  Returns the
        injector for counter inspection (``fired`` / ``crashes``).
        """
        injector = FaultInjector(self.network, plan,
                                 crash_fn=self._crash_site)
        self.faults = injector
        self.network.arm_faults(injector)
        if self.scheduler is not None:
            self.scheduler.faults = injector
        return injector

    def _crash_site(self, site: str) -> int:
        """CrashEvent hook: crash every user file server hosted at
        ``site`` (auth state and subscriptions drop — the paper's
        crontab restart maps to ``Session.remount()`` afterwards)."""
        crashed = 0
        for s in self.sessions:
            if s.server.endpoint.name == site:
                s.server.crash()
                crashed += 1
        return crashed

    # ---- background maintenance ------------------------------------------
    def maintenance_report(self) -> Optional[MaintenanceReport]:
        """Snapshot of the maintenance plane (None when no
        :class:`MaintenanceSpec` was declared)."""
        return self.scheduler.report() if self.scheduler is not None \
            else None

    def _register_maintenance(self, owner: str, site: str, home: str,
                              client: XufsClient,
                              rset: Optional[ReplicaSet]) -> None:
        """Register one session's periodic upkeep on the shared scheduler.

        Task closures read the client's live mount/lease tables at run
        time, so a later ``remount()`` (which swaps LeaseManagers and
        tokens) is picked up without re-registration.  Registration
        itself touches no wire.
        """
        sched = self.scheduler
        if sched is None:
            return
        # conflicts this client detects at reconcile time surface on the
        # shared MaintenanceReport (sibling of the dead-letter record)
        client._conflict_sink = sched.note_conflict
        spec = self.spec.maintenance
        tag = f"{owner}@{site}"
        net = self.network

        def lease_tick() -> int:
            # renewal first; anything a partition leaves at risk is
            # re-verified on the next (retry) tick once the link heals.
            # Unresolved at-risk leases are a task FAILURE: the retry
            # ladder (and ultimately the dead-letter record) makes a
            # silently-expiring lock an observable event.
            renewed = 0
            at_risk = 0
            for lm in client.leases.values():
                if lm.at_risk:
                    lm.reverify_at_risk()
                renewed += lm.renew_all()
                at_risk += len(lm.at_risk)
            if at_risk:
                raise DisconnectedError(
                    f"{tag}: {at_risk} lease(s) at risk after renewal")
            return renewed

        sched.register(f"lease:{tag}", lease_tick,
                       period_s=spec.lease_period_s, owner=tag)

        def reconcile_tick() -> int:
            return client.reconcile()

        sched.register(f"reconcile:{tag}", reconcile_tick,
                       period_s=spec.reconcile_period_s, owner=tag)

        if rset is None:
            return
        key = sched.rset_key(rset)

        def resync_tick() -> int:
            # anti-entropy originates at the client site: a partition
            # between site and home fails the task into the retry /
            # backoff / dead-letter ladder instead of silently skipping
            # convergence
            net.rpc(site, home, "resync_vector")
            if not sched.locks.acquire(f"{key}/resync", tag,
                                       now=net.clock):
                return 0      # a peer session is already resyncing
            parked = {r.path for r in client.oplog.unreconciled()}
            return rset.resync(skip=parked)

        sched.register(f"resync:{tag}", resync_tick,
                       period_s=spec.resync_period_s, owner=tag)

        def repair_tick() -> int:
            launched = 0
            for path in rset.repair_targets():
                if not sched.locks.acquire(f"{key}/{path}", tag,
                                           now=net.clock):
                    continue  # a peer holds the repair lease: skip, never
                    #           double-repair (the conflict is counted)
                pending = rset.begin_repair_path(path)
                if pending:
                    sched.note_repair(f"{key}/{path}", tag)
                    sched.track(rset, pending)
                    launched += 1
            return launched

        sched.register(f"repair:{tag}", repair_tick,
                       period_s=spec.repair_period_s, owner=tag)

        ev = rset.eviction
        if ev is None:
            return
        for rname in rset.replicas:
            # one evict task per capacity-bounded replica, fabric-wide:
            # sessions sharing the ReplicaSet (attach) must not scan the
            # same replica twice per period — first registration wins
            task_name = f"evict:{key}/{rname}"
            if task_name in sched.tasks:
                continue

            # the lease holder is the EVICTOR, not the session: repair
            # ticks registered under the session tag must contend (and
            # lose) against a live eviction lease on the same path —
            # sharing the session tag would let same-owner renewal
            # silently bypass the eviction/repair mutual exclusion
            evict_owner = f"evict:{tag}"

            def evict_tick(rname: str = rname) -> int:
                rep = rset.replicas[rname]
                if rep.resident_bytes <= ev.high_bytes:
                    return 0          # under the watermark: wire-free scan
                # over the high watermark: the scan probes the replica so
                # a partition fails the task into the retry / backoff /
                # dead-letter ladder instead of silently skipping the trim
                net.rpc(site, rname, "evict_scan")
                evicted = 0
                for path in rset.eviction_candidates(rname):
                    if rep.resident_bytes <= ev.low_bytes:
                        break         # trimmed down to the low watermark
                    if not sched.locks.acquire(f"{key}/{path}",
                                               evict_owner,
                                               now=net.clock):
                        continue      # repair (or a peer evictor) holds
                        #               the path lease: never race it
                    rset.evict_path(rname, path)
                    sched.evictions += 1
                    evicted += 1
                return evicted

            sched.register(task_name, evict_tick,
                           period_s=ev.scan_period_s, owner=tag)

    # ---- sessions --------------------------------------------------------
    def login(self, user: str, *, home: str = "home", site: str = "site",
              mounts: Optional[Sequence[MountSpec]] = None,
              replicas: Optional[ReplicaPolicy] = None,
              home_root: Optional[str] = None,
              site_root: Optional[str] = None) -> Session:
        """USSH login onto the declared topology (paper §3.2).

        Starts ``user``'s personal file server at the ``home`` site,
        authenticates the ``site``-side client over the HMAC challenge,
        places read replicas per ``replicas`` (every named site must be
        declared in the spec; undeclared ``home <-> replica`` links are
        resolved by the latency-composition rule in the module
        docstring), and mounts each :class:`MountSpec` (default: a bare
        ``home/`` mount).  Sessions are recorded in ``self.sessions`` —
        any number of users share the one topology.
        """
        home_dir = self._site_root(home, home_root, "home space")
        site_dir = self._site_root(site, site_root, "client cache")
        mounts = tuple(mounts) if mounts is not None else (MountSpec("home/"),)
        prefixes = [ms.prefix for ms in mounts]
        if len(set(prefixes)) != len(prefixes):
            dupes = sorted({p for p in prefixes if prefixes.count(p) > 1})
            raise ValueError(f"duplicate mount prefixes: {dupes}")
        if replicas is not None:
            for rname in replicas.sites:
                self.spec.site(rname)           # KeyError on a topo typo
        kp = KeyPhrase.generate()
        store = HomeStore(os.path.join(home_dir, user),
                          endpoint=self.network.endpoint(home),
                          keyphrase=kp)
        server = UserFileServer(user=user,
                                endpoint=self.network.endpoint(home),
                                store=store)
        # SSH-authenticated login, then challenge-auth the data connections
        self.network.rpc(site, home, "ssh_login", encrypted=True)
        token = _authenticate(server)
        rset: Optional[ReplicaSet] = None
        if replicas is not None and replicas.sites:
            rset = ReplicaSet(network=self.network, home_name=home,
                              home_store=store, token=token,
                              write_quorum=replicas.write_quorum,
                              queue_aware=replicas.queue_aware,
                              eviction=replicas.eviction,
                              write_lease=replicas.write_lease,
                              bulk=replicas.bulk if replicas.bulk
                              is not None else self.spec.bulk)
            for rname in replicas.sites:
                if not self.network.has_link(home, rname):
                    # replica sites are near the compute site but WAN-far
                    # from home: compose the undeclared path through the
                    # site region, so fan-out applies to different
                    # replicas finish at distinct times (what makes W<N
                    # drain time beat W=all under overlap).  A link
                    # already on the live network — spec-declared or
                    # composed by an earlier login — is never
                    # overwritten: a second user logging in from a
                    # different compute site must not retime the first
                    # session's fan-out path.
                    self.network.set_link(home, rname, _dc_replace(
                        self.network.link,
                        latency_s=self.network.link.latency_s +
                        self.network.latency_between(site, rname)))
                rstore = HomeStore(
                    os.path.join(home_dir, ".replicas", rname, user),
                    endpoint=self.network.endpoint(rname))
                rset.add_replica(rname, rstore)
        client = XufsClient(site, self.network,
                            cache_root=os.path.join(site_dir, user, "cache"),
                            oplog_root=os.path.join(site_dir, user, "oplog"),
                            owner=user)
        mount_specs: Dict[str, MountSpec] = {}
        for ms in mounts:
            client.mount(ms.prefix, home, store, token,
                         localized=list(ms.localized), replicas=rset)
            mount_specs[ms.prefix] = ms
        session = Session(user=user, network=self.network, server=server,
                          client=client, token=token, replicas=rset,
                          mount_specs=mount_specs,
                          scheduler=self.scheduler)
        self.sessions.append(session)
        self._register_maintenance(user, site, home, client, rset)
        return session

    def attach(self, session: Session, site: str, *, owner: str,
               mounts: Sequence[MountSpec],
               site_root: Optional[str] = None) -> XufsClient:
        """A further reader at ``site`` joins an existing session's home
        space (the paper's shared-project-data case): its own cache,
        oplog, and auth token on the shared topology, reusing the
        session's replica fabric.  The home store still authenticates
        the newcomer over the HMAC challenge — attach grants no
        ambient authority."""
        site_dir = self._site_root(site, site_root, "client cache")
        token = _authenticate(session.server)
        client = XufsClient(site, self.network,
                            cache_root=os.path.join(site_dir, owner,
                                                    "cache"),
                            oplog_root=os.path.join(site_dir, owner,
                                                    "oplog"),
                            owner=owner)
        for ms in mounts:
            client.mount(ms.prefix, session.server.endpoint.name,
                         session.server.store, token,
                         localized=list(ms.localized),
                         replicas=session.replicas)
        # the attached reader shares the session's replica fabric, so its
        # repair task competes for the SAME per-path locks — this is the
        # two-sessions-never-double-repair case the lock table exists for
        self._register_maintenance(owner, site,
                                   session.server.endpoint.name, client,
                                   session.replicas)
        return client
