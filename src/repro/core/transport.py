"""Simulated WAN transport with a deterministic virtual clock.

The container is one CPU process, so the *wire* is modeled while every
protocol above it (striping, callbacks, leases, auth, WAL replay) is real
code moving real bytes between in-process endpoints.

Link model (paper context: TeraGrid 30 Gbps WAN, high RTT):
  * per-stream throughput is TCP-window/RTT limited (``per_stream_bw``) —
    the reason XUFS stripes transfers (§3.3);
  * the aggregate link caps at ``link_bw``;
  * every RPC pays one ``latency_s``.

Failures: ``partition(a, b[, duration])`` makes RPCs raise
:class:`DisconnectedError` until ``heal`` (or until the virtual clock passes
the deadline) — this is how tests exercise XUFS disconnected operation.
"""
from __future__ import annotations

import hashlib
import hmac as hmac_mod
import os
import secrets
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024


class DisconnectedError(ConnectionError):
    """The WAN link between two endpoints is down."""


class QuorumNotReachedError(DisconnectedError):
    """Fewer than W of the N write endpoints acknowledged an apply.

    Subclasses :class:`DisconnectedError` because a missed quorum is a
    connectivity-induced stall: the flusher stops draining and the op
    stays queued (with its partial acks persisted) until links heal.
    """


class AuthError(PermissionError):
    """HMAC challenge failed."""


@dataclass
class LinkModel:
    latency_s: float = 0.030          # one-way WAN latency (SDSC<->NCSA era)
    per_stream_bw: float = 80 * MB    # TCP window-limited single stream
    link_bw: float = 3.75 * GB        # 30 Gbps
    crypto_bw: float = 25 * MB        # single-stream *encrypted* (SCP-like)

    def transfer_time(self, nbytes: int, n_streams: int = 1,
                      encrypted: bool = False) -> float:
        if nbytes <= 0:
            return self.latency_s
        if encrypted:
            eff = min(self.crypto_bw * max(n_streams, 1), self.link_bw)
        else:
            eff = min(self.per_stream_bw * max(n_streams, 1), self.link_bw)
        return self.latency_s + nbytes / eff


@dataclass
class Network:
    """Endpoint registry + virtual clock + partition schedule.

    The default ``link`` models every pair; ``set_link`` overrides a single
    pair (e.g. a nearby replica site with a fraction of the home RTT).
    Per-endpoint RPC/byte counters let tests and benchmarks assert *where*
    traffic went, not just how much.
    """

    link: LinkModel = field(default_factory=LinkModel)
    clock: float = 0.0
    bytes_sent: int = 0
    rpc_count: int = 0
    _partitions: Dict[Tuple[str, str], float] = field(default_factory=dict)
    _endpoints: Dict[str, "Endpoint"] = field(default_factory=dict)
    _links: Dict[Tuple[str, str], LinkModel] = field(default_factory=dict)
    per_endpoint_rpcs: Dict[str, int] = field(default_factory=dict)
    per_endpoint_bytes: Dict[str, int] = field(default_factory=dict)
    per_pair_rpcs: Dict[Tuple[str, str], int] = field(default_factory=dict)
    per_pair_bytes: Dict[Tuple[str, str], int] = field(default_factory=dict)

    # ---- endpoints ----------------------------------------------------
    def register(self, ep: "Endpoint") -> None:
        self._endpoints[ep.name] = ep

    def endpoint(self, name: str) -> "Endpoint":
        return self._endpoints[name]

    # ---- per-pair links -------------------------------------------------
    def set_link(self, a: str, b: str, link: LinkModel) -> None:
        self._links[(min(a, b), max(a, b))] = link

    def link_between(self, a: str, b: str) -> LinkModel:
        return self._links.get((min(a, b), max(a, b)), self.link)

    def latency_between(self, a: str, b: str) -> float:
        return self.link_between(a, b).latency_s

    # ---- time ----------------------------------------------------------
    def advance(self, seconds: float) -> None:
        self.clock += max(seconds, 0.0)

    # ---- failures --------------------------------------------------------
    def partition(self, a: str, b: str, duration: float = float("inf")):
        key = (min(a, b), max(a, b))
        self._partitions[key] = self.clock + duration

    def heal(self, a: str, b: str) -> None:
        self._partitions.pop((min(a, b), max(a, b)), None)

    def is_partitioned(self, a: str, b: str) -> bool:
        key = (min(a, b), max(a, b))
        until = self._partitions.get(key)
        if until is None:
            return False
        if self.clock >= until:
            del self._partitions[key]
            return False
        return True

    # ---- data plane ------------------------------------------------------
    def rpc(self, src: str, dst: str, method: str, payload_bytes: int = 0,
            n_streams: int = 1, encrypted: bool = False) -> float:
        """Account one RPC; returns the modeled elapsed seconds."""
        if self.is_partitioned(src, dst):
            raise DisconnectedError(f"{src} <-> {dst} partitioned")
        dt = self.link_between(src, dst).transfer_time(payload_bytes,
                                                       n_streams, encrypted)
        self.advance(dt)
        self.bytes_sent += payload_bytes
        self.rpc_count += 1
        self.account(src, payload_bytes)
        self.account(dst, payload_bytes)
        pair = (min(src, dst), max(src, dst))
        self.per_pair_rpcs[pair] = self.per_pair_rpcs.get(pair, 0) + 1
        self.per_pair_bytes[pair] = \
            self.per_pair_bytes.get(pair, 0) + payload_bytes
        return dt

    def pair_rpcs(self, a: str, b: str) -> int:
        """RPCs that crossed the ``a <-> b`` link (ack accounting reads
        this to assert quorum round-trips went over the right pairs)."""
        return self.per_pair_rpcs.get((min(a, b), max(a, b)), 0)

    def account(self, endpoint: str, payload_bytes: int = 0,
                rpcs: int = 1) -> None:
        """Attribute traffic to one end of a link (rpc charges both ends,
        so ``per_endpoint_rpcs[name]`` reads as 'traffic touching name')."""
        self.per_endpoint_rpcs[endpoint] = \
            self.per_endpoint_rpcs.get(endpoint, 0) + rpcs
        self.per_endpoint_bytes[endpoint] = \
            self.per_endpoint_bytes.get(endpoint, 0) + payload_bytes


@dataclass
class Endpoint:
    """A named party on the network (home workstation, pod host, ...)."""

    name: str
    network: Network

    def __post_init__(self) -> None:
        self.network.register(self)

    def call(self, dst: str, method: str, payload_bytes: int = 0,
             n_streams: int = 1, encrypted: bool = False) -> float:
        return self.network.rpc(self.name, dst, method, payload_bytes,
                                n_streams, encrypted)


# ---------------------------------------------------------------------------
# USSH-style <key, phrase> challenge authentication (paper §3.2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KeyPhrase:
    key: str
    phrase: str

    @classmethod
    def generate(cls) -> "KeyPhrase":
        return cls(key=secrets.token_hex(16), phrase=secrets.token_hex(16))


def make_challenge() -> str:
    return secrets.token_hex(16)


def respond(kp: KeyPhrase, challenge: str) -> str:
    return hmac_mod.new(kp.key.encode(), (challenge + kp.phrase).encode(),
                        hashlib.sha256).hexdigest()


def verify(kp: KeyPhrase, challenge: str, response: str) -> bool:
    return hmac_mod.compare_digest(respond(kp, challenge), response)
