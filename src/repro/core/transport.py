"""Simulated WAN transport with a deterministic per-channel virtual clock.

The container is one CPU process, so the *wire* is modeled while every
protocol above it (striping, callbacks, leases, auth, WAL replay) is real
code moving real bytes between in-process endpoints.

Time is event-based: every ``(endpoint, endpoint)`` pair owns a pool of
*channels* (modeled parallel TCP connections, at most
``channels_per_pair``), each with a ``busy_until`` time.  ``transfer()``
*reserves* a channel — start = max(clock, channel busy, ``not_before``) —
and returns a :class:`Transfer` record carrying start/completion times
without touching the global clock.  Callers advance the clock explicitly:
``wait(t)`` to one completion, ``wait_all(ts)`` to the max of a group,
``drain()`` to the max of everything outstanding.  Overlapped elapsed time
is therefore the max over channels, not the sum — which is what lets
striped transfers, replica write fan-out, and pipelined prefetch actually
overlap on the virtual clock (see ``docs/transport.md``).  ``rpc()``
remains the synchronous reserve-then-wait wrapper for request/response
calls (stat, lock, callback probes).

Link model (paper context: TeraGrid 30 Gbps WAN, high RTT):
  * per-stream throughput is TCP-window/RTT limited (``per_stream_bw``) —
    the reason XUFS stripes transfers (§3.3);
  * the aggregate link caps at ``link_bw`` (``stream_time`` grants each of
    k concurrent streams a ``link_bw / k`` share at most);
  * every transfer pays one ``latency_s``.

NIC model: an endpoint may carry an optional aggregate bandwidth budget
(``set_nic_budget``) shared by its uplink and downlink across ALL pairs.
Each reservation additionally serializes its payload through both
endpoints' NICs at the budget rate; when concurrent reservations across
different pairs oversubscribe an endpoint, completion stretches to the
NIC backlog (``docs/transport.md`` has the math).  With no budget set
the reservation math is bit-for-bit the pure link formula.
``estimated_completion()`` exposes the same arithmetic — static latency
+ channel queue depth + NIC backlog — without reserving, which is what
queue-aware replica routing ranks candidates by.

Failures: ``partition(a, b[, duration])`` makes reservations raise
:class:`DisconnectedError` until ``heal`` (or until the virtual clock passes
the deadline) — this is how tests exercise XUFS disconnected operation.
"""
from __future__ import annotations

import hashlib
import hmac as hmac_mod
import os
import secrets
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024


class DisconnectedError(ConnectionError):
    """The WAN link between two endpoints is down."""


class QuorumNotReachedError(DisconnectedError):
    """Fewer than W of the N write endpoints acknowledged an apply.

    Subclasses :class:`DisconnectedError` because a missed quorum is a
    connectivity-induced stall: the flusher stops draining and the op
    stays queued (with its partial acks persisted) until links heal.
    """


class AuthError(PermissionError):
    """HMAC challenge failed."""


@dataclass
class LinkModel:
    latency_s: float = 0.030          # one-way WAN latency (SDSC<->NCSA era)
    per_stream_bw: float = 80 * MB    # TCP window-limited single stream
    link_bw: float = 3.75 * GB        # 30 Gbps
    crypto_bw: float = 25 * MB        # single-stream *encrypted* (SCP-like)

    def transfer_time(self, nbytes: int, n_streams: int = 1,
                      encrypted: bool = False) -> float:
        """Aggregate time for ``nbytes`` over ``n_streams`` modeled as ONE
        reservation (the legacy ``rpc(n_streams=...)`` path)."""
        if nbytes <= 0:
            return self.latency_s
        if encrypted:
            eff = min(self.crypto_bw * max(n_streams, 1), self.link_bw)
        else:
            eff = min(self.per_stream_bw * max(n_streams, 1), self.link_bw)
        return self.latency_s + nbytes / eff

    def stream_time(self, nbytes: int, concurrency: int = 1,
                    encrypted: bool = False) -> float:
        """Time for ONE stream carrying ``nbytes`` while ``concurrency``
        streams share the pair: window-limited per-stream bandwidth, but
        never more than an even ``link_bw`` share."""
        if nbytes <= 0:
            return self.latency_s
        bw = self.crypto_bw if encrypted else self.per_stream_bw
        eff = min(bw, self.link_bw / max(concurrency, 1))
        return self.latency_s + nbytes / eff


@dataclass(eq=False)
class Transfer:
    """One reserved channel occupancy: the unit of overlapped time.

    ``start``/``completion`` are virtual-clock times fixed at reservation;
    the global clock advances only when a caller waits on the record.
    Identity (not value) equality: two byte-identical transfers are still
    distinct wire events.
    """

    src: str
    dst: str
    method: str
    nbytes: int
    start: float
    completion: float
    channel: int          # index into the pair's channel pool
    settled: bool = False   # a caller waited on it (or it aged past clock)

    @property
    def elapsed(self) -> float:
        return self.completion - self.start

    @property
    def pair(self) -> Tuple[str, str]:
        return (min(self.src, self.dst), max(self.src, self.dst))


@dataclass
class Network:
    """Endpoint registry + per-channel virtual clock + partition schedule.

    The default ``link`` models every pair; ``set_link`` overrides a single
    pair (e.g. a nearby replica site with a fraction of the home RTT).
    Per-endpoint RPC/byte counters let tests and benchmarks assert *where*
    traffic went, not just how much.  ``trace`` records reservations
    ``(src, dst, method, nbytes, channel, start, completion)`` in issue
    order — the determinism witness (same ops => identical trace) — and
    keeps the first ``trace_limit`` so a long-lived network stays
    bounded (truncation is itself deterministic).
    """

    link: LinkModel = field(default_factory=LinkModel)
    clock: float = 0.0
    bytes_sent: int = 0
    rpc_count: int = 0
    channels_per_pair: int = 12       # parallel TCP connections per pair
    trace_limit: int = 100_000        # reservations recorded (first N)
    _partitions: Dict[Tuple[str, str], float] = field(default_factory=dict)
    _endpoints: Dict[str, "Endpoint"] = field(default_factory=dict)
    _links: Dict[Tuple[str, str], LinkModel] = field(default_factory=dict)
    _channels: Dict[Tuple[str, str], List[float]] = field(default_factory=dict)
    _outstanding: List[Transfer] = field(default_factory=list)
    _prune_watermark: int = 256
    nic_budgets: Dict[str, float] = field(default_factory=dict)
    _nic_free: Dict[str, float] = field(default_factory=dict)
    trace: List[Tuple] = field(default_factory=list)
    per_endpoint_rpcs: Dict[str, int] = field(default_factory=dict)
    per_endpoint_bytes: Dict[str, int] = field(default_factory=dict)
    per_endpoint_busy_s: Dict[str, float] = field(default_factory=dict)
    per_pair_rpcs: Dict[Tuple[str, str], int] = field(default_factory=dict)
    per_pair_bytes: Dict[Tuple[str, str], int] = field(default_factory=dict)

    # ---- endpoints ----------------------------------------------------
    def register(self, ep: "Endpoint") -> None:
        self._endpoints[ep.name] = ep

    def endpoint(self, name: str) -> "Endpoint":
        return self._endpoints[name]

    # ---- per-pair links -------------------------------------------------
    def set_link(self, a: str, b: str, link: LinkModel) -> None:
        self._links[(min(a, b), max(a, b))] = link

    def link_between(self, a: str, b: str) -> LinkModel:
        return self._links.get((min(a, b), max(a, b)), self.link)

    def has_link(self, a: str, b: str) -> bool:
        """Whether the pair carries a specific link (set_link) rather
        than riding the network default."""
        return (min(a, b), max(a, b)) in self._links

    def latency_between(self, a: str, b: str) -> float:
        return self.link_between(a, b).latency_s

    # ---- per-endpoint NIC budgets ---------------------------------------
    def set_nic_budget(self, endpoint: str,
                       bytes_per_s: Optional[float]) -> None:
        """Cap ``endpoint``'s aggregate NIC bandwidth (uplink + downlink
        share it, across ALL pairs).  ``None`` removes the cap — the
        default, under which reservations reproduce the pure link
        formula bit-for-bit."""
        if bytes_per_s is None:
            self.nic_budgets.pop(endpoint, None)
            # drop the serializer backlog too: an uncapped interval
            # drains the queue, so a later re-applied budget must not
            # inherit phantom queueing from before the cap was lifted
            self._nic_free.pop(endpoint, None)
            return
        if bytes_per_s <= 0:
            raise ValueError(f"NIC budget must be > 0: {bytes_per_s}")
        self.nic_budgets[endpoint] = bytes_per_s

    def nic_budget(self, endpoint: str) -> Optional[float]:
        return self.nic_budgets.get(endpoint)

    def _charge_nic(self, endpoint: str, start: float, nbytes: int,
                    completion: float) -> float:
        """Serialize ``nbytes`` through ``endpoint``'s NIC at the budget
        rate (FIFO in reservation order — deterministic): the payload's
        NIC service occupies ``[max(backlog, start), +nbytes/budget)``,
        so aggregate bytes through the endpoint can never exceed
        budget x busy-span.  Returns the (possibly stretched)
        completion."""
        bw = self.nic_budgets.get(endpoint)
        if bw is None or nbytes <= 0:
            return completion
        free = max(self._nic_free.get(endpoint, 0.0), start) + nbytes / bw
        self._nic_free[endpoint] = free
        return completion if free <= completion else free

    # ---- time ----------------------------------------------------------
    def advance(self, seconds: float) -> None:
        """Push the clock forward unconditionally (lease-expiry tests and
        workload idle time; data movement should reserve channels)."""
        self.clock += max(seconds, 0.0)

    def wait(self, t: Transfer) -> float:
        """Block on one transfer: clock lands at its completion (no-op if
        the clock already passed it).  Returns the completion time."""
        t.settled = True
        self.clock = max(self.clock, t.completion)
        return t.completion

    def wait_all(self, transfers: Optional[List[Transfer]] = None) -> float:
        """Block on a group (default: everything outstanding): the clock
        advances to the max completion — the overlapped elapsed time."""
        targets = self.outstanding() if transfers is None else transfers
        for t in targets:
            self.wait(t)
        return self.clock

    def drain(self) -> float:
        """Settle every outstanding transfer (fire-and-forget fan-out,
        pipelined fills) and return the clock."""
        return self.wait_all()

    def _prune_outstanding(self) -> None:
        """Drop settled records and ones the clock already passed (waiting
        on those is a no-op) — fire-and-forget traffic must not grow the
        list or slow later calls."""
        self._outstanding = [t for t in self._outstanding
                             if not t.settled and t.completion > self.clock]

    def outstanding(self) -> List[Transfer]:
        """Transfers still in flight at the current clock."""
        self._prune_outstanding()
        return list(self._outstanding)

    # ---- failures --------------------------------------------------------
    def partition(self, a: str, b: str, duration: float = float("inf")):
        key = (min(a, b), max(a, b))
        self._partitions[key] = self.clock + duration

    def heal(self, a: str, b: str) -> None:
        self._partitions.pop((min(a, b), max(a, b)), None)

    def is_partitioned(self, a: str, b: str) -> bool:
        key = (min(a, b), max(a, b))
        until = self._partitions.get(key)
        if until is None:
            return False
        if self.clock >= until:
            del self._partitions[key]
            return False
        return True

    # ---- data plane ------------------------------------------------------
    def _peek_reserve(self, pair: Tuple[str, str],
                      not_before: float = 0.0) -> Tuple[int, float, bool]:
        """The channel :meth:`_reserve` would pick, without reserving:
        the lowest-index idle one, else a new one (up to
        ``channels_per_pair``), else the earliest-free channel.  Returns
        (index, start time, whether the channel would be new)."""
        chans = self._channels.get(pair, ())
        t0 = max(self.clock, not_before)
        for i, busy in enumerate(chans):
            if busy <= t0:
                return i, t0, False
        if len(chans) < self.channels_per_pair:
            return len(chans), t0, True
        i = min(range(len(chans)), key=lambda j: chans[j])
        return i, max(chans[i], t0), False

    def _reserve(self, pair: Tuple[str, str],
                 not_before: float = 0.0) -> Tuple[int, float]:
        """Pick a channel deterministically and claim it."""
        i, start, new = self._peek_reserve(pair, not_before)
        if new:
            self._channels.setdefault(pair, []).append(start)
        return i, start

    def estimated_completion(self, src: str, dst: str, nbytes: int = 0,
                             *, not_before: float = 0.0) -> float:
        """Completion time a single-stream transfer reserved *now* would
        get — static link time + channel queue depth + NIC backlog at
        both endpoints — WITHOUT reserving anything.  A partitioned pair
        estimates to ``inf``.  This is the queue-aware routing metric:
        for an idle network it reduces to ``clock + latency +
        nbytes/eff``, so ranking by it degenerates to the static
        nearest-by-latency order."""
        if self.is_partitioned(src, dst):
            return float("inf")
        pair = (min(src, dst), max(src, dst))
        _i, start, _new = self._peek_reserve(pair, not_before)
        completion = start + self.link_between(src, dst).stream_time(nbytes)
        if nbytes > 0:
            for ep in (src, dst):
                bw = self.nic_budgets.get(ep)
                if bw is not None:
                    backlog = max(self._nic_free.get(ep, 0.0), start)
                    completion = max(completion, backlog + nbytes / bw)
        return completion

    def transfer(self, src: str, dst: str, method: str,
                 payload_bytes: int = 0, *, n_streams: int = 1,
                 concurrency: int = 1, encrypted: bool = False,
                 not_before: float = 0.0) -> Transfer:
        """Reserve a channel for one transfer; the clock does NOT move.

        ``concurrency`` declares how many sibling streams share the pair
        right now (per-stripe bandwidth share); ``n_streams > 1`` instead
        models an n-stream aggregate as one reservation (legacy RPC
        surface).  ``not_before`` chains causally-dependent transfers
        (an ack cannot start before its data lands).  The caller later
        advances the clock via ``wait``/``wait_all``/``drain``.
        """
        if self.is_partitioned(src, dst):
            raise DisconnectedError(f"{src} <-> {dst} partitioned")
        link = self.link_between(src, dst)
        if n_streams > 1:
            dt = link.transfer_time(payload_bytes, n_streams, encrypted)
        else:
            dt = link.stream_time(payload_bytes, concurrency, encrypted)
        pair = (min(src, dst), max(src, dst))
        channel, start = self._reserve(pair, not_before)
        completion = start + dt
        # both NICs serialize the payload at their budget rate; an
        # oversubscribed endpoint stretches completion to its backlog
        completion = self._charge_nic(src, start, payload_bytes, completion)
        completion = self._charge_nic(dst, start, payload_bytes, completion)
        self._channels[pair][channel] = completion
        t = Transfer(src=src, dst=dst, method=method, nbytes=payload_bytes,
                     start=start, completion=completion, channel=channel)
        if len(self._outstanding) >= self._prune_watermark:
            self._prune_outstanding()
            # doubling watermark: amortized O(1) even when nothing prunes
            self._prune_watermark = max(256, 2 * len(self._outstanding))
        self._outstanding.append(t)
        if len(self.trace) < self.trace_limit:
            self.trace.append((src, dst, method, payload_bytes, channel,
                               round(start, 9), round(completion, 9)))
        self.bytes_sent += payload_bytes
        self.rpc_count += 1
        self.account(src, payload_bytes)
        self.account(dst, payload_bytes)
        dur = completion - start
        self.per_endpoint_busy_s[src] = \
            self.per_endpoint_busy_s.get(src, 0.0) + dur
        self.per_endpoint_busy_s[dst] = \
            self.per_endpoint_busy_s.get(dst, 0.0) + dur
        self.per_pair_rpcs[pair] = self.per_pair_rpcs.get(pair, 0) + 1
        self.per_pair_bytes[pair] = \
            self.per_pair_bytes.get(pair, 0) + payload_bytes
        return t

    def rpc(self, src: str, dst: str, method: str, payload_bytes: int = 0,
            n_streams: int = 1, encrypted: bool = False) -> float:
        """Synchronous request/response: reserve a channel and wait on it.
        Returns the elapsed seconds the caller observed (queueing
        included) — identical to the pre-channel-clock behavior whenever
        the pair has an idle channel."""
        t0 = self.clock
        self.wait(self.transfer(src, dst, method, payload_bytes,
                                n_streams=n_streams, encrypted=encrypted))
        return self.clock - t0

    def pair_rpcs(self, a: str, b: str) -> int:
        """RPCs that crossed the ``a <-> b`` link (ack accounting reads
        this to assert quorum round-trips went over the right pairs)."""
        return self.per_pair_rpcs.get((min(a, b), max(a, b)), 0)

    def account(self, endpoint: str, payload_bytes: int = 0,
                rpcs: int = 1) -> None:
        """Attribute traffic to one end of a link (rpc charges both ends,
        so ``per_endpoint_rpcs[name]`` reads as 'traffic touching name')."""
        self.per_endpoint_rpcs[endpoint] = \
            self.per_endpoint_rpcs.get(endpoint, 0) + rpcs
        self.per_endpoint_bytes[endpoint] = \
            self.per_endpoint_bytes.get(endpoint, 0) + payload_bytes


@dataclass
class Endpoint:
    """A named party on the network (home workstation, pod host, ...)."""

    name: str
    network: Network

    def __post_init__(self) -> None:
        self.network.register(self)

    def call(self, dst: str, method: str, payload_bytes: int = 0,
             n_streams: int = 1, encrypted: bool = False) -> float:
        return self.network.rpc(self.name, dst, method, payload_bytes,
                                n_streams, encrypted)


# ---------------------------------------------------------------------------
# USSH-style <key, phrase> challenge authentication (paper §3.2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KeyPhrase:
    key: str
    phrase: str

    @classmethod
    def generate(cls) -> "KeyPhrase":
        return cls(key=secrets.token_hex(16), phrase=secrets.token_hex(16))


def make_challenge() -> str:
    return secrets.token_hex(16)


def respond(kp: KeyPhrase, challenge: str) -> str:
    return hmac_mod.new(kp.key.encode(), (challenge + kp.phrase).encode(),
                        hashlib.sha256).hexdigest()


def verify(kp: KeyPhrase, challenge: str, response: str) -> bool:
    return hmac_mod.compare_digest(respond(kp, challenge), response)
