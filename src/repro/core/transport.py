"""Simulated WAN transport with a deterministic per-channel virtual clock.

The container is one CPU process, so the *wire* is modeled while every
protocol above it (striping, callbacks, leases, auth, WAL replay) is real
code moving real bytes between in-process endpoints.

Time is event-based: every ``(endpoint, endpoint)`` pair owns a pool of
*channels* (modeled parallel TCP connections, at most
``channels_per_pair``), each with a ``busy_until`` time.  ``transfer()``
*reserves* a channel — start = max(clock, channel busy, ``not_before``) —
and returns a :class:`Transfer` record carrying start/completion times
without touching the global clock.  Callers advance the clock explicitly:
``wait(t)`` to one completion, ``wait_all(ts)`` to the max of a group,
``drain()`` to the max of everything outstanding.  Overlapped elapsed time
is therefore the max over channels, not the sum — which is what lets
striped transfers, replica write fan-out, and pipelined prefetch actually
overlap on the virtual clock (see ``docs/transport.md``).  ``rpc()``
remains the synchronous reserve-then-wait wrapper for request/response
calls (stat, lock, callback probes).

The engine is a batched discrete-event core (``docs/transport.md`` —
"event engine"): reservations land in a heap-based event queue popped in
completion order, per-pair channel state lives in a preallocated numpy
array, and N same-epoch reservations can be priced in ONE vectorized
pass via :meth:`Network.transfer_batch` (with
:meth:`Network.estimate_batch` as the vectorized routing metric).  The
batch paths are bit-identical to issuing the same reservations one at a
time with :meth:`Network.transfer` — same trace, same channel/NIC state
— which is what keeps every gated benchmark topology valid.

Link model (paper context: TeraGrid 30 Gbps WAN, high RTT):
  * per-stream throughput is TCP-window/RTT limited (``per_stream_bw``) —
    the reason XUFS stripes transfers (§3.3);
  * the aggregate link caps at ``link_bw`` (``stream_time`` grants each of
    k concurrent streams a ``link_bw / k`` share at most);
  * every transfer pays one ``latency_s``.

NIC model: an endpoint may carry an optional aggregate bandwidth budget
(``set_nic_budget``) shared by its uplink and downlink across ALL pairs.
Each reservation additionally serializes its payload through both
endpoints' NICs at the budget rate; when concurrent reservations across
different pairs oversubscribe an endpoint, completion stretches to the
NIC backlog (``docs/transport.md`` has the math).  With no budget set
the reservation math is bit-for-bit the pure link formula.
``estimated_completion()`` exposes the same arithmetic — static latency
+ channel queue depth + NIC backlog — without reserving, which is what
queue-aware replica routing ranks candidates by.

Failures: ``partition(a, b[, duration])`` makes reservations raise
:class:`DisconnectedError` until ``heal`` (or until the virtual clock passes
the deadline) — this is how tests exercise XUFS disconnected operation.
"""
from __future__ import annotations

import hashlib
import heapq
import hmac as hmac_mod
import os
import secrets
from dataclasses import dataclass, field
from typing import (
    Any, Callable, Dict, List, NamedTuple, Optional, Sequence, Tuple,
)

import numpy as np

KB = 1024
MB = 1024 * 1024
GB = 1024 * 1024 * 1024


class DisconnectedError(ConnectionError):
    """The WAN link between two endpoints is down."""


class QuorumNotReachedError(DisconnectedError):
    """Fewer than W of the N write endpoints acknowledged an apply.

    Subclasses :class:`DisconnectedError` because a missed quorum is a
    connectivity-induced stall: the flusher stops draining and the op
    stays queued (with its partial acks persisted) until links heal.
    """


class AuthError(PermissionError):
    """HMAC challenge failed."""


@dataclass
class LinkModel:
    latency_s: float = 0.030          # one-way WAN latency (SDSC<->NCSA era)
    per_stream_bw: float = 80 * MB    # TCP window-limited single stream
    link_bw: float = 3.75 * GB        # 30 Gbps
    crypto_bw: float = 25 * MB        # single-stream *encrypted* (SCP-like)

    def transfer_time(self, nbytes: int, n_streams: int = 1,
                      encrypted: bool = False) -> float:
        """Aggregate time for ``nbytes`` over ``n_streams`` modeled as ONE
        reservation (the legacy ``rpc(n_streams=...)`` path)."""
        if nbytes <= 0:
            return self.latency_s
        if encrypted:
            eff = min(self.crypto_bw * max(n_streams, 1), self.link_bw)
        else:
            eff = min(self.per_stream_bw * max(n_streams, 1), self.link_bw)
        return self.latency_s + nbytes / eff

    def stream_time(self, nbytes: int, concurrency: int = 1,
                    encrypted: bool = False) -> float:
        """Time for ONE stream carrying ``nbytes`` while ``concurrency``
        streams share the pair: window-limited per-stream bandwidth, but
        never more than an even ``link_bw`` share."""
        if nbytes <= 0:
            return self.latency_s
        bw = self.crypto_bw if encrypted else self.per_stream_bw
        eff = min(bw, self.link_bw / max(concurrency, 1))
        return self.latency_s + nbytes / eff


@dataclass(eq=False)
class Transfer:
    """One reserved channel occupancy: the unit of overlapped time.

    ``start``/``completion`` are virtual-clock times fixed at reservation;
    the global clock advances only when a caller waits on the record.
    Identity (not value) equality: two byte-identical transfers are still
    distinct wire events.
    """

    src: str
    dst: str
    method: str
    nbytes: int
    start: float
    completion: float
    channel: int          # index into the pair's channel pool
    settled: bool = False   # a caller waited on it (or it aged past clock)

    @property
    def elapsed(self) -> float:
        return self.completion - self.start

    @property
    def pair(self) -> Tuple[str, str]:
        return (min(self.src, self.dst), max(self.src, self.dst))

    def settle(self) -> None:
        self.settled = True


class TransferRequest(NamedTuple):
    """One row of a :meth:`Network.transfer_batch` call.  Plain tuples
    of ``(src, dst, method[, nbytes[, concurrency[, encrypted[,
    not_before]]]])`` are accepted too."""

    src: str
    dst: str
    method: str
    nbytes: int = 0
    concurrency: int = 1
    encrypted: bool = False
    not_before: float = 0.0


class TransferBatch:
    """N same-epoch reservations priced in one vectorized pass.

    Carries the reservation results as numpy arrays; the event queue
    holds the whole batch as ONE entry keyed by its max completion, so
    draining a 100k-reservation wave costs one heap pop.  ``transfers``
    materializes per-reservation :class:`Transfer` records lazily (the
    scalar-compatibility view — most batch callers never need it).
    """

    __slots__ = ("srcs", "dsts", "methods", "nbytes", "starts",
                 "completions", "channels", "completion", "settled",
                 "_transfers")

    def __init__(self, srcs: List[str], dsts: List[str],
                 methods: List[str], nbytes: List[int],
                 starts: np.ndarray, completions: np.ndarray,
                 channels: np.ndarray,
                 transfers: Optional[List[Transfer]] = None):
        self.srcs = srcs
        self.dsts = dsts
        self.methods = methods
        self.nbytes = nbytes
        self.starts = starts
        self.completions = completions
        self.channels = channels
        self.completion = float(completions.max()) if len(srcs) else 0.0
        self.settled = False
        self._transfers = transfers

    def __len__(self) -> int:
        return len(self.srcs)

    @property
    def transfers(self) -> List[Transfer]:
        """Per-reservation records (materialized on first access)."""
        if self._transfers is None:
            st = self.starts.tolist()
            co = self.completions.tolist()
            ch = self.channels.tolist()
            self._transfers = [
                Transfer(src=self.srcs[i], dst=self.dsts[i],
                         method=self.methods[i], nbytes=self.nbytes[i],
                         start=st[i], completion=co[i], channel=ch[i],
                         settled=self.settled)
                for i in range(len(self.srcs))
            ]
        return self._transfers

    def settle(self) -> None:
        self.settled = True
        if self._transfers is not None:
            for t in self._transfers:
                t.settled = True


_GROW = 64      # initial/minimum id-table array capacity


@dataclass
class Network:
    """Endpoint registry + per-channel virtual clock + partition schedule.

    The default ``link`` models every pair; ``set_link`` overrides a single
    pair (e.g. a nearby replica site with a fraction of the home RTT).
    Per-endpoint RPC/byte counters let tests and benchmarks assert *where*
    traffic went, not just how much.  ``trace`` records reservations
    ``(src, dst, method, nbytes, channel, start, completion)`` in issue
    order — the determinism witness (same ops => identical trace) — and
    keeps the first ``trace_limit`` so a long-lived network stays
    bounded (truncation is itself deterministic).

    Internally endpoints and pairs are interned to dense integer ids:
    channel ``busy_until`` state is one preallocated ``(n_pairs,
    channels_per_pair)`` float array (an untouched slot at 0.0 is
    indistinguishable from the old create-on-demand channel list), link
    parameters are cached per pair id for the vectorized paths, and
    completions queue in a heap popped in time order.
    """

    link: LinkModel = field(default_factory=LinkModel)
    clock: float = 0.0
    bytes_sent: int = 0
    rpc_count: int = 0
    # byte provenance for replica-apply payloads (wire-free accounting):
    # third-party = moved storage->storage (home->replica or
    # replica->replica); client-mediated = pushed from a client session's
    # endpoint.  The bulk plane's offload witness (docs/maintenance.md).
    bytes_third_party: int = 0
    bytes_client_mediated: int = 0
    channels_per_pair: int = 12       # parallel TCP connections per pair
    trace_limit: int = 100_000        # reservations recorded (first N)
    _partitions: Dict[Tuple[str, str], float] = field(default_factory=dict)
    _endpoints: Dict[str, "Endpoint"] = field(default_factory=dict)
    _links: Dict[Tuple[str, str], LinkModel] = field(default_factory=dict)
    nic_budgets: Dict[str, float] = field(default_factory=dict)
    _nic_free: Dict[str, float] = field(default_factory=dict)
    trace: List[Tuple] = field(default_factory=list)
    # armed FaultInjector (repro.core.faults), pumped lazily before any
    # partition-sensitive operation; None => zero-cost no-op
    _faults: Optional[Any] = None

    def __post_init__(self) -> None:
        w = max(int(self.channels_per_pair), 1)
        # interned ids: endpoint name -> eid, ordered pair -> pid
        self._ep_ids: Dict[str, int] = {}
        self._ep_names: List[str] = []
        self._pair_ids: Dict[Tuple[str, str], int] = {}
        self._pair_keys: List[Tuple[str, str]] = []
        # per-pair channel state + cached link parameters (pid-indexed)
        self._chan_busy = np.zeros((0, w))
        self._pair_lat = np.zeros(0)
        self._pair_psbw = np.zeros(0)
        self._pair_lbw = np.zeros(0)
        self._pair_cbw = np.zeros(0)
        # heap-based event queue: (completion, seq, Transfer|TransferBatch)
        self._event_heap: List[Tuple[float, int, Any]] = []
        self._event_seq = 0
        # accounting: the dicts are the source of truth; batch paths
        # accumulate into id-indexed scratch arrays flushed on read
        self._pe_rpcs: Dict[str, int] = {}
        self._pe_bytes: Dict[str, int] = {}
        self._pe_busy: Dict[str, float] = {}
        self._pp_rpcs: Dict[Tuple[str, str], int] = {}
        self._pp_bytes: Dict[Tuple[str, str], int] = {}
        self._acct_ep_rpcs = np.zeros(0, np.int64)
        self._acct_ep_bytes = np.zeros(0, np.int64)
        self._acct_ep_busy = np.zeros(0)
        self._acct_pair_rpcs = np.zeros(0, np.int64)
        self._acct_pair_bytes = np.zeros(0, np.int64)
        self._acct_dirty = False

    # ---- endpoints ----------------------------------------------------
    def register(self, ep: "Endpoint") -> None:
        self._endpoints[ep.name] = ep
        self._ep_id(ep.name)

    def endpoint(self, name: str) -> "Endpoint":
        return self._endpoints[name]

    def prealloc(self, names: Sequence[str]) -> None:
        """Intern a declared site set up front: endpoint ids plus every
        site-to-site pair, so a fabric's steady-state traffic never pays
        id registration or array growth mid-run."""
        names = list(names)
        for nm in names:
            self._ep_id(nm)
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                self.pair_id(a, b)

    def _ep_id(self, name: str) -> int:
        eid = self._ep_ids.get(name)
        if eid is None:
            eid = len(self._ep_names)
            self._ep_ids[name] = eid
            self._ep_names.append(name)
            if eid >= self._acct_ep_rpcs.shape[0]:
                grow = max(_GROW, self._acct_ep_rpcs.shape[0])
                self._acct_ep_rpcs = np.concatenate(
                    [self._acct_ep_rpcs, np.zeros(grow, np.int64)])
                self._acct_ep_bytes = np.concatenate(
                    [self._acct_ep_bytes, np.zeros(grow, np.int64)])
                self._acct_ep_busy = np.concatenate(
                    [self._acct_ep_busy, np.zeros(grow)])
        return eid

    def pair_id(self, a: str, b: str) -> int:
        """Dense id of the unordered ``(a, b)`` pair (interned on first
        use).  Hot drivers may precompute these and hand them to
        :meth:`estimate_batch` to skip per-call name lookups."""
        key = (a, b) if a <= b else (b, a)
        pid = self._pair_ids.get(key)
        if pid is None:
            pid = self._register_pair(key)
        return pid

    def intern_pairs(self, srcs: Sequence[str],
                     dsts: Sequence[str]) -> np.ndarray:
        """Bulk :meth:`pair_id`: intern N ``(src, dst)`` pairs in one
        pass and return their dense ids.  Capacity growth and
        link-parameter caching are amortized over the whole call — the
        setup path for drivers that price the same candidate set every
        wave (precompute once, hand the ids to
        :meth:`estimate_batch`)."""
        pair_ids = self._pair_ids
        pair_keys = self._pair_keys
        first_new = len(pair_keys)
        new_keys: List[Tuple[str, str]] = []
        out: List[int] = []
        append = out.append
        setdefault = pair_ids.setdefault
        nxt = first_new
        for a, b in zip(srcs, dsts):
            key = (a, b) if a <= b else (b, a)
            pid = setdefault(key, nxt)
            if pid == nxt:
                pair_keys.append(key)
                new_keys.append(key)
                nxt += 1
            append(pid)
        if new_keys:
            need = len(pair_keys)
            self._ensure_pair_capacity(need)
            # every new pair rides the network default; the (rare)
            # set_link overrides are fixed up after the bulk fill
            lk = self.link
            sl = slice(first_new, need)
            self._pair_lat[sl] = lk.latency_s
            self._pair_psbw[sl] = lk.per_stream_bw
            self._pair_lbw[sl] = lk.link_bw
            self._pair_cbw[sl] = lk.crypto_bw
            if self._links:
                links = self._links
                for j, key in enumerate(new_keys):
                    ov = links.get(key)
                    if ov is not None:
                        self._cache_pair_link(first_new + j, ov)
        return np.array(out, dtype=np.intp)

    def _ensure_pair_capacity(self, need: int) -> None:
        cap = self._chan_busy.shape[0]
        if need <= cap:
            return
        grow = max(need - cap, _GROW, cap)
        self._chan_busy = np.vstack(
            [self._chan_busy,
             np.zeros((grow, self._chan_busy.shape[1]))])
        z = np.zeros(grow)
        self._pair_lat = np.concatenate([self._pair_lat, z])
        self._pair_psbw = np.concatenate([self._pair_psbw, z.copy()])
        self._pair_lbw = np.concatenate([self._pair_lbw, z.copy()])
        self._pair_cbw = np.concatenate([self._pair_cbw, z.copy()])
        self._acct_pair_rpcs = np.concatenate(
            [self._acct_pair_rpcs, np.zeros(grow, np.int64)])
        self._acct_pair_bytes = np.concatenate(
            [self._acct_pair_bytes, np.zeros(grow, np.int64)])

    def _register_pair(self, key: Tuple[str, str]) -> int:
        pid = len(self._pair_keys)
        self._pair_ids[key] = pid
        self._pair_keys.append(key)
        self._ensure_pair_capacity(pid + 1)
        self._cache_pair_link(pid, self._links.get(key, self.link))
        return pid

    def _cache_pair_link(self, pid: int, lk: LinkModel) -> None:
        self._pair_lat[pid] = lk.latency_s
        self._pair_psbw[pid] = lk.per_stream_bw
        self._pair_lbw[pid] = lk.link_bw
        self._pair_cbw[pid] = lk.crypto_bw

    def _ensure_chan_width(self) -> None:
        # channels_per_pair raised after construction: pad idle columns
        # (a 0.0 column behaves exactly like a newly creatable channel).
        # Lowering it mid-run is unsupported.
        w = self._chan_busy.shape[1]
        cpp = int(self.channels_per_pair)
        if cpp > w:
            self._chan_busy = np.hstack(
                [self._chan_busy,
                 np.zeros((self._chan_busy.shape[0], cpp - w))])

    # ---- per-pair links -------------------------------------------------
    def set_link(self, a: str, b: str, link: LinkModel) -> None:
        key = (min(a, b), max(a, b))
        self._links[key] = link
        pid = self._pair_ids.get(key)
        if pid is not None:
            self._cache_pair_link(pid, link)

    def link_between(self, a: str, b: str) -> LinkModel:
        return self._links.get((min(a, b), max(a, b)), self.link)

    def has_link(self, a: str, b: str) -> bool:
        """Whether the pair carries a specific link (set_link) rather
        than riding the network default."""
        return (min(a, b), max(a, b)) in self._links

    def latency_between(self, a: str, b: str) -> float:
        return self.link_between(a, b).latency_s

    # ---- per-endpoint NIC budgets ---------------------------------------
    def set_nic_budget(self, endpoint: str,
                       bytes_per_s: Optional[float]) -> None:
        """Cap ``endpoint``'s aggregate NIC bandwidth (uplink + downlink
        share it, across ALL pairs).  ``None`` removes the cap — the
        default, under which reservations reproduce the pure link
        formula bit-for-bit."""
        if bytes_per_s is None:
            self.nic_budgets.pop(endpoint, None)
            # drop the serializer backlog too: an uncapped interval
            # drains the queue, so a later re-applied budget must not
            # inherit phantom queueing from before the cap was lifted
            self._nic_free.pop(endpoint, None)
            return
        if bytes_per_s <= 0:
            raise ValueError(f"NIC budget must be > 0: {bytes_per_s}")
        self.nic_budgets[endpoint] = bytes_per_s

    def nic_budget(self, endpoint: str) -> Optional[float]:
        return self.nic_budgets.get(endpoint)

    # ---- byte provenance ------------------------------------------------
    def note_provenance(self, kind: str, nbytes: int) -> None:
        """Attribute ``nbytes`` of replica-apply payload to its source
        class: ``"third_party"`` (storage->storage movement) or
        ``"client_mediated"`` (pushed off a client session's NIC).
        Pure accounting — touches no wire, no clock, no trace."""
        if kind == "third_party":
            self.bytes_third_party += int(nbytes)
        elif kind == "client_mediated":
            self.bytes_client_mediated += int(nbytes)
        else:
            raise ValueError(f"unknown provenance kind: {kind!r}")

    def _charge_nic(self, endpoint: str, start: float, nbytes: int,
                    completion: float) -> float:
        """Serialize ``nbytes`` through ``endpoint``'s NIC at the budget
        rate (FIFO in reservation order — deterministic): the payload's
        NIC service occupies ``[max(backlog, start), +nbytes/budget)``,
        so aggregate bytes through the endpoint can never exceed
        budget x busy-span.  Returns the (possibly stretched)
        completion."""
        bw = self.nic_budgets.get(endpoint)
        if bw is None or nbytes <= 0:
            return completion
        free = max(self._nic_free.get(endpoint, 0.0), start) + nbytes / bw
        self._nic_free[endpoint] = free
        return completion if free <= completion else free

    # ---- time ----------------------------------------------------------
    def advance(self, seconds: float) -> None:
        """Push the clock forward unconditionally (lease-expiry tests and
        workload idle time; data movement should reserve channels)."""
        self.clock += max(seconds, 0.0)
        if self._faults is not None:
            self._faults.advance_to(self.clock)

    def wait(self, t: Transfer) -> float:
        """Block on one transfer: clock lands at its completion (no-op if
        the clock already passed it).  Returns the completion time."""
        t.settled = True
        if t.completion > self.clock:
            self.clock = t.completion
        return t.completion

    def wait_all(self, transfers: Optional[List[Transfer]] = None) -> float:
        """Block on a group (default: everything outstanding): the clock
        advances to the max completion — the overlapped elapsed time."""
        if transfers is None:
            return self._drain_events()
        clock = self.clock
        for t in transfers:
            t.settled = True
            if t.completion > clock:
                clock = t.completion
        self.clock = clock
        return clock

    def wait_batch(self, batch: TransferBatch) -> float:
        """Block on a whole reservation batch: one clock advance to its
        max completion (``wait_all(batch.transfers)`` without ever
        materializing the per-reservation records)."""
        batch.settle()
        if batch.completion > self.clock:
            self.clock = batch.completion
        return batch.completion

    def drain(self) -> float:
        """Settle every outstanding transfer (fire-and-forget fan-out,
        pipelined fills) and return the clock."""
        return self._drain_events()

    def _drain_events(self) -> float:
        """Pop the event queue dry in completion order; the clock lands
        on the last (= max) completion popped."""
        h = self._event_heap
        clock = self.clock
        while h:
            completion, _seq, item = heapq.heappop(h)
            item.settle()
            if completion > clock:
                clock = completion
        self.clock = clock
        return clock

    def _push_event(self, completion: float, item: Any) -> None:
        """Queue a completion event; entries the clock already passed
        are pruned from the top on the way in (amortized O(1)), so
        fire-and-forget traffic never grows the queue."""
        h = self._event_heap
        clock = self.clock
        while h and h[0][0] <= clock:
            heapq.heappop(h)[2].settle()
        self._event_seq += 1
        heapq.heappush(h, (completion, self._event_seq, item))

    def outstanding(self) -> List[Transfer]:
        """Transfers still in flight at the current clock (issue order).
        Diagnostic view — materializes batched reservations."""
        h = self._event_heap
        clock = self.clock
        while h and h[0][0] <= clock:
            heapq.heappop(h)[2].settle()
        live: List[Tuple[int, int, Transfer]] = []
        for completion, seq, item in h:
            if item.settled:
                continue
            if isinstance(item, TransferBatch):
                live.extend((seq, i, t)
                            for i, t in enumerate(item.transfers)
                            if t.completion > clock and not t.settled)
            elif completion > clock:
                live.append((seq, 0, item))
        live.sort(key=lambda e: (e[0], e[1]))
        return [t for _seq, _i, t in live]

    # ---- failures --------------------------------------------------------
    def arm_faults(self, injector: Any) -> None:
        """Attach a :class:`repro.core.faults.FaultInjector`.  Scheduled
        events fire lazily: any partition-sensitive operation (and
        :meth:`advance`) first releases every event whose time the clock
        has reached.  Pass ``None`` to disarm."""
        self._faults = injector

    def _pump_faults(self) -> None:
        f = self._faults
        if f is not None:
            f.advance_to(self.clock)

    def partition(self, a: str, b: str, duration: float = float("inf"),
                  *, start: Optional[float] = None):
        """Cut the ``a <-> b`` link.  ``start`` anchors the outage window
        at an earlier virtual time (fault plans fire lazily, so the
        window must not depend on when the pump happened to run); a
        window already fully in the past is a no-op."""
        t0 = self.clock if start is None else start
        until = t0 + duration
        if until <= self.clock:
            return
        self._partitions[(min(a, b), max(a, b))] = until

    def heal(self, a: str, b: str) -> None:
        self._partitions.pop((min(a, b), max(a, b)), None)

    def is_partitioned(self, a: str, b: str) -> bool:
        if self._faults is not None:
            self._faults.advance_to(self.clock)
        key = (min(a, b), max(a, b))
        until = self._partitions.get(key)
        if until is None:
            return False
        if self.clock >= until:
            del self._partitions[key]
            return False
        return True

    # ---- data plane ------------------------------------------------------
    def _peek_reserve(self, pair: Tuple[str, str],
                      not_before: float = 0.0) -> Tuple[int, float, bool]:
        """The channel :meth:`_reserve` would pick, without reserving:
        the lowest-index idle one (an untouched array slot at 0.0 IS the
        old "new channel"), else the earliest-free channel by argmin.
        Returns (index, start time, whether the pair is untouched)."""
        t0 = self.clock if self.clock >= not_before else not_before
        pid = self._pair_ids.get(pair)
        if pid is None:
            return 0, t0, True
        self._ensure_chan_width()
        row = self._chan_busy[pid]
        busy = row.tolist()
        for i, b in enumerate(busy):
            if b <= t0:
                return i, t0, False
        i = int(row.argmin())
        b = busy[i]
        return i, (b if b > t0 else t0), False

    def _reserve(self, pair: Tuple[str, str],
                 not_before: float = 0.0) -> Tuple[int, float]:
        """Pick a channel deterministically and claim it."""
        i, start, new = self._peek_reserve(pair, not_before)
        if new:
            self._register_pair(pair)
        return i, start

    def estimated_completion(self, src: str, dst: str, nbytes: int = 0,
                             *, not_before: float = 0.0) -> float:
        """Completion time a single-stream transfer reserved *now* would
        get — static link time + channel queue depth + NIC backlog at
        both endpoints — WITHOUT reserving anything.  A partitioned pair
        estimates to ``inf``.  This is the queue-aware routing metric:
        for an idle network it reduces to ``clock + latency +
        nbytes/eff``, so ranking by it degenerates to the static
        nearest-by-latency order."""
        if self.is_partitioned(src, dst):
            return float("inf")
        pair = (min(src, dst), max(src, dst))
        _i, start, _new = self._peek_reserve(pair, not_before)
        completion = start + self.link_between(src, dst).stream_time(nbytes)
        if nbytes > 0:
            for ep in (src, dst):
                bw = self.nic_budgets.get(ep)
                if bw is not None:
                    backlog = max(self._nic_free.get(ep, 0.0), start)
                    completion = max(completion, backlog + nbytes / bw)
        return completion

    def estimate_batch(self, srcs, dsts, nbytes=0, *,
                       not_before: float = 0.0,
                       pair_ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Vectorized :meth:`estimated_completion` over N candidate
        routes in one pass — the queue-aware routing metric for a whole
        wave.  ``srcs``/``dsts`` are name sequences (either may be a
        single string, broadcast against the other); ``nbytes`` and
        ``not_before`` are scalars or per-candidate arrays.  Read-only:
        nothing is reserved, so duplicate pairs are fine.  Element i is
        float-identical to ``estimated_completion(srcs[i], dsts[i],
        ...)`` called in isolation.  ``pair_ids`` (from
        :meth:`pair_id`) skips the per-call name interning for hot
        drivers."""
        if isinstance(srcs, str):
            srcs = [srcs] * (1 if isinstance(dsts, str) else len(dsts))
        if isinstance(dsts, str):
            dsts = [dsts] * len(srcs)
        n = len(srcs)
        if n == 0:
            return np.zeros(0)
        if pair_ids is None:
            pair_ids = np.array(
                [self.pair_id(s, d) for s, d in zip(srcs, dsts)],
                dtype=np.intp)
        self._ensure_chan_width()
        rows = self._chan_busy[pair_ids]
        nb = np.asarray(nbytes, dtype=np.float64)
        if nb.ndim == 0:
            nb = np.full(n, float(nb))
        t0 = np.maximum(
            np.broadcast_to(np.asarray(not_before, dtype=np.float64), (n,)),
            self.clock)
        has_idle = (rows <= t0[:, None]).any(axis=1)
        start = np.where(has_idle, t0, rows.min(axis=1))
        lat = self._pair_lat[pair_ids]
        eff = np.minimum(self._pair_psbw[pair_ids], self._pair_lbw[pair_ids])
        est = start + np.where(nb > 0, lat + nb / eff, lat)
        if self.nic_budgets:
            budgets = self.nic_budgets
            nic_free = self._nic_free
            st_l = start.tolist()
            est_l = est.tolist()
            nb_l = nb.tolist()
            touched = False
            for i in range(n):
                nbi = nb_l[i]
                if nbi <= 0:
                    continue
                c = est_l[i]
                for ep in (srcs[i], dsts[i]):
                    bw = budgets.get(ep)
                    if bw is not None:
                        backlog = max(nic_free.get(ep, 0.0), st_l[i])
                        v = backlog + nbi / bw
                        if v > c:
                            c = v
                if c != est_l[i]:
                    est_l[i] = c
                    touched = True
            if touched:
                est = np.array(est_l)
        if self._faults is not None:
            self._faults.advance_to(self.clock)
        if self._partitions:
            for i in range(n):
                if self.is_partitioned(srcs[i], dsts[i]):
                    est[i] = np.inf
        return est

    def transfer(self, src: str, dst: str, method: str,
                 payload_bytes: int = 0, *, n_streams: int = 1,
                 concurrency: int = 1, encrypted: bool = False,
                 not_before: float = 0.0) -> Transfer:
        """Reserve a channel for one transfer; the clock does NOT move.

        ``concurrency`` declares how many sibling streams share the pair
        right now (per-stripe bandwidth share); ``n_streams > 1`` instead
        models an n-stream aggregate as one reservation (legacy RPC
        surface).  ``not_before`` chains causally-dependent transfers
        (an ack cannot start before its data lands).  The caller later
        advances the clock via ``wait``/``wait_all``/``drain``.
        """
        if self._faults is not None:
            self._faults.advance_to(self.clock)
        if self._partitions and self.is_partitioned(src, dst):
            raise DisconnectedError(f"{src} <-> {dst} partitioned")
        key = (src, dst) if src <= dst else (dst, src)
        link = self._links.get(key)
        if link is None:
            link = self.link
        if n_streams > 1:
            dt = link.transfer_time(payload_bytes, n_streams, encrypted)
        else:
            dt = link.stream_time(payload_bytes, concurrency, encrypted)
        pid = self._pair_ids.get(key)
        if pid is None:
            pid = self._register_pair(key)
        self._ensure_chan_width()
        row = self._chan_busy[pid]
        t0 = self.clock if self.clock >= not_before else not_before
        busy = row.tolist()
        channel = -1
        start = t0
        for i, b in enumerate(busy):
            if b <= t0:
                channel = i
                break
        if channel < 0:
            channel = int(row.argmin())
            b = busy[channel]
            if b > t0:
                start = b
        completion = start + dt
        # both NICs serialize the payload at their budget rate; an
        # oversubscribed endpoint stretches completion to its backlog
        if self.nic_budgets:
            completion = self._charge_nic(src, start, payload_bytes,
                                          completion)
            completion = self._charge_nic(dst, start, payload_bytes,
                                          completion)
        self._chan_busy[pid, channel] = completion
        t = Transfer(src=src, dst=dst, method=method, nbytes=payload_bytes,
                     start=start, completion=completion, channel=channel)
        self._push_event(completion, t)
        if len(self.trace) < self.trace_limit:
            self.trace.append((src, dst, method, payload_bytes, channel,
                               round(start, 9), round(completion, 9)))
        self.bytes_sent += payload_bytes
        self.rpc_count += 1
        pe = self._pe_rpcs
        pe[src] = pe.get(src, 0) + 1
        pe[dst] = pe.get(dst, 0) + 1
        pb = self._pe_bytes
        pb[src] = pb.get(src, 0) + payload_bytes
        pb[dst] = pb.get(dst, 0) + payload_bytes
        dur = completion - start
        bz = self._pe_busy
        bz[src] = bz.get(src, 0.0) + dur
        bz[dst] = bz.get(dst, 0.0) + dur
        self._pp_rpcs[key] = self._pp_rpcs.get(key, 0) + 1
        self._pp_bytes[key] = self._pp_bytes.get(key, 0) + payload_bytes
        return t

    def transfer_batch(self, reqs: Sequence, *,
                       pair_ids: Optional[np.ndarray] = None
                       ) -> TransferBatch:
        """Reserve N transfers in one same-epoch pass; the clock does
        NOT move.  ``reqs`` rows are :class:`TransferRequest` (or plain
        ``(src, dst, method[, nbytes[, concurrency[, encrypted[,
        not_before]]]])`` tuples).  ``pair_ids`` (from :meth:`pair_id` /
        :meth:`intern_pairs`, one id per row) skips the per-row pair
        interning for hot drivers — it must describe exactly these rows.

        Contract: the resulting channel/NIC state, accounting, and trace
        are identical to issuing the rows one at a time with
        :meth:`transfer` in order.  Batches whose pairs are all distinct
        take a fully vectorized path (same-epoch rows on distinct pairs
        cannot interact, so pricing them simultaneously IS sequential
        pricing); duplicate-pair batches fall back to the sequential
        scalar path, as does any batch touching a partitioned pair
        (which must raise mid-application exactly where a sequential
        caller would)."""
        reqs = reqs if isinstance(reqs, list) else list(reqs)
        n = len(reqs)
        if n == 0:
            empty = np.zeros(0)
            b = TransferBatch([], [], [], [], empty, empty,
                              np.zeros(0, np.intp), transfers=[])
            b.completion = self.clock
            b.settled = True
            return b
        lens = set(map(len, reqs))
        if len(lens) == 1:
            # uniform-arity rows: transpose at C speed
            lr = lens.pop()
            cols = list(zip(*reqs))
            srcs = list(cols[0])
            dsts = list(cols[1])
            methods = list(cols[2])
            nbs = list(cols[3]) if lr > 3 else [0] * n
            concs = list(cols[4]) if lr > 4 else [1] * n
            encs = list(cols[5]) if lr > 5 else [False] * n
            nbefs = list(cols[6]) if lr > 6 else [0.0] * n
        else:
            srcs, dsts, methods = [], [], []
            nbs, concs, encs, nbefs = [], [], [], []
            for r in reqs:
                lr = len(r)
                srcs.append(r[0])
                dsts.append(r[1])
                methods.append(r[2])
                nbs.append(r[3] if lr > 3 else 0)
                concs.append(r[4] if lr > 4 else 1)
                encs.append(r[5] if lr > 5 else False)
                nbefs.append(r[6] if lr > 6 else 0.0)
        sequential = False
        if self._faults is not None:
            self._faults.advance_to(self.clock)
        if self._partitions:
            for src, dst in zip(srcs, dsts):
                if self.is_partitioned(src, dst):
                    sequential = True
                    break
        if pair_ids is not None:
            pid_arr = np.asarray(pair_ids, dtype=np.intp)
            if not sequential and np.unique(pid_arr).size != n:
                sequential = True
        else:
            table = self._pair_ids
            pids: List[int] = []
            seen: set = set()
            for src, dst in zip(srcs, dsts):
                key = (src, dst) if src <= dst else (dst, src)
                pid = table.get(key)
                if pid is None:
                    pid = self._register_pair(key)
                if pid in seen:
                    sequential = True
                else:
                    seen.add(pid)
                pids.append(pid)
            pid_arr = np.array(pids, dtype=np.intp)
        if sequential:
            # duplicate pairs interact through channel state (and a
            # partitioned pair must raise after the partial prefix
            # applied), so replay through the scalar path — exactly what
            # the contract promises anyway
            ts = [self.transfer(srcs[i], dsts[i], methods[i], nbs[i],
                                concurrency=concs[i], encrypted=encs[i],
                                not_before=nbefs[i])
                  for i in range(n)]
            return TransferBatch(
                srcs, dsts, methods, nbs,
                np.array([t.start for t in ts]),
                np.array([t.completion for t in ts]),
                np.array([t.channel for t in ts], dtype=np.intp),
                transfers=ts)
        self._ensure_chan_width()
        nb_arr = np.array(nbs, dtype=np.float64)
        t0 = np.maximum(np.array(nbefs, dtype=np.float64), self.clock)
        rows = self._chan_busy[pid_arr]
        le = rows <= t0[:, None]
        has_idle = le.any(axis=1)
        # np.argmax/argmin return the FIRST hit — the scalar tie-breaks
        chan = np.where(has_idle, le.argmax(axis=1), rows.argmin(axis=1))
        start = np.maximum(rows[np.arange(n), chan], t0)
        lat = self._pair_lat[pid_arr]
        bw = np.where(np.array(encs, dtype=bool),
                      self._pair_cbw[pid_arr], self._pair_psbw[pid_arr])
        eff = np.minimum(
            bw, self._pair_lbw[pid_arr] /
            np.maximum(np.array(concs, dtype=np.int64), 1))
        completion = start + np.where(nb_arr > 0, lat + nb_arr / eff, lat)
        if self.nic_budgets:
            budgets = self.nic_budgets
            if any(s in budgets or d in budgets
                   for s, d in zip(srcs, dsts)):
                # the NIC backlog is a serial max/add chain — replaying
                # it per budgeted endpoint in request order (src before
                # dst, as the scalar path charges) is the only
                # bit-exact evaluation
                nic_free = self._nic_free
                st_l = start.tolist()
                co_l = completion.tolist()
                for i in range(n):
                    nb = nbs[i]
                    if nb <= 0:
                        continue
                    c = co_l[i]
                    s = st_l[i]
                    for ep in (srcs[i], dsts[i]):
                        bwd = budgets.get(ep)
                        if bwd is not None:
                            free = max(nic_free.get(ep, 0.0), s) + nb / bwd
                            nic_free[ep] = free
                            if free > c:
                                c = free
                    co_l[i] = c
                completion = np.array(co_l)
        self._chan_busy[pid_arr, chan] = completion
        batch = TransferBatch(srcs, dsts, methods, nbs, start, completion,
                              chan)
        self._push_event(batch.completion, batch)
        if len(self.trace) < self.trace_limit:
            room = self.trace_limit - len(self.trace)
            st_l = start.tolist()
            co_l = completion.tolist()
            ch_l = chan.tolist()
            trace = self.trace
            for i in range(n if n < room else room):
                # Python round, not np.round: the trace is the
                # bit-identity witness against the scalar path
                trace.append((srcs[i], dsts[i], methods[i], nbs[i],
                              ch_l[i], round(st_l[i], 9),
                              round(co_l[i], 9)))
        self.bytes_sent += int(sum(nbs))
        self.rpc_count += n
        # fast path when every endpoint is already interned (steady
        # state); first contact falls back to the registering loop
        d = self._ep_ids
        try:
            sid = np.fromiter(map(d.__getitem__, srcs), np.intp, n)
            did = np.fromiter(map(d.__getitem__, dsts), np.intp, n)
        except KeyError:
            sid = np.array([self._ep_id(s) for s in srcs], dtype=np.intp)
            did = np.array([self._ep_id(d) for d in dsts], dtype=np.intp)
        nb_i = np.array(nbs, dtype=np.int64)
        dur = completion - start
        np.add.at(self._acct_ep_rpcs, sid, 1)
        np.add.at(self._acct_ep_rpcs, did, 1)
        np.add.at(self._acct_ep_bytes, sid, nb_i)
        np.add.at(self._acct_ep_bytes, did, nb_i)
        np.add.at(self._acct_ep_busy, sid, dur)
        np.add.at(self._acct_ep_busy, did, dur)
        np.add.at(self._acct_pair_rpcs, pid_arr, 1)
        np.add.at(self._acct_pair_bytes, pid_arr, nb_i)
        self._acct_dirty = True
        return batch

    def rpc(self, src: str, dst: str, method: str, payload_bytes: int = 0,
            n_streams: int = 1, encrypted: bool = False) -> float:
        """Synchronous request/response: reserve a channel and wait on it.
        Returns the elapsed seconds the caller observed (queueing
        included) — identical to the pre-channel-clock behavior whenever
        the pair has an idle channel."""
        t0 = self.clock
        self.wait(self.transfer(src, dst, method, payload_bytes,
                                n_streams=n_streams, encrypted=encrypted))
        return self.clock - t0

    # ---- accounting ------------------------------------------------------
    def _flush_accounting(self) -> None:
        """Fold the batch scratch arrays into the counter dicts.  All
        counters are commutative sums, so interleaved scalar updates and
        deferred batch flushes land on the same totals."""
        self._acct_dirty = False
        er = self._acct_ep_rpcs
        idx = np.nonzero(er)[0]
        if idx.size:
            eb = self._acct_ep_bytes
            ez = self._acct_ep_busy
            for i in idx.tolist():
                name = self._ep_names[i]
                self._pe_rpcs[name] = self._pe_rpcs.get(name, 0) + int(er[i])
                self._pe_bytes[name] = \
                    self._pe_bytes.get(name, 0) + int(eb[i])
                self._pe_busy[name] = \
                    self._pe_busy.get(name, 0.0) + float(ez[i])
            er[idx] = 0
            eb[idx] = 0
            ez[idx] = 0.0
        pr = self._acct_pair_rpcs
        idx = np.nonzero(pr)[0]
        if idx.size:
            pb = self._acct_pair_bytes
            for i in idx.tolist():
                key = self._pair_keys[i]
                self._pp_rpcs[key] = self._pp_rpcs.get(key, 0) + int(pr[i])
                self._pp_bytes[key] = \
                    self._pp_bytes.get(key, 0) + int(pb[i])
            pr[idx] = 0
            pb[idx] = 0

    @property
    def per_endpoint_rpcs(self) -> Dict[str, int]:
        if self._acct_dirty:
            self._flush_accounting()
        return self._pe_rpcs

    @property
    def per_endpoint_bytes(self) -> Dict[str, int]:
        if self._acct_dirty:
            self._flush_accounting()
        return self._pe_bytes

    @property
    def per_endpoint_busy_s(self) -> Dict[str, float]:
        if self._acct_dirty:
            self._flush_accounting()
        return self._pe_busy

    @property
    def per_pair_rpcs(self) -> Dict[Tuple[str, str], int]:
        if self._acct_dirty:
            self._flush_accounting()
        return self._pp_rpcs

    @property
    def per_pair_bytes(self) -> Dict[Tuple[str, str], int]:
        if self._acct_dirty:
            self._flush_accounting()
        return self._pp_bytes

    def pair_rpcs(self, a: str, b: str) -> int:
        """RPCs that crossed the ``a <-> b`` link (ack accounting reads
        this to assert quorum round-trips went over the right pairs)."""
        if self._acct_dirty:
            self._flush_accounting()
        return self._pp_rpcs.get((min(a, b), max(a, b)), 0)

    def account(self, endpoint: str, payload_bytes: int = 0,
                rpcs: int = 1) -> None:
        """Attribute traffic to one end of a link (rpc charges both ends,
        so ``per_endpoint_rpcs[name]`` reads as 'traffic touching name')."""
        self._pe_rpcs[endpoint] = self._pe_rpcs.get(endpoint, 0) + rpcs
        self._pe_bytes[endpoint] = \
            self._pe_bytes.get(endpoint, 0) + payload_bytes


@dataclass
class Endpoint:
    """A named party on the network (home workstation, pod host, ...)."""

    name: str
    network: Network

    def __post_init__(self) -> None:
        self.network.register(self)

    def call(self, dst: str, method: str, payload_bytes: int = 0,
             n_streams: int = 1, encrypted: bool = False) -> float:
        return self.network.rpc(self.name, dst, method, payload_bytes,
                                n_streams, encrypted)


# ---------------------------------------------------------------------------
# USSH-style <key, phrase> challenge authentication (paper §3.2)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class KeyPhrase:
    key: str
    phrase: str

    @classmethod
    def generate(cls) -> "KeyPhrase":
        return cls(key=secrets.token_hex(16), phrase=secrets.token_hex(16))


def make_challenge() -> str:
    return secrets.token_hex(16)


def respond(kp: KeyPhrase, challenge: str) -> str:
    return hmac_mod.new(kp.key.encode(), (challenge + kp.phrase).encode(),
                        hashlib.sha256).hexdigest()


def verify(kp: KeyPhrase, challenge: str, response: str) -> bool:
    return hmac_mod.compare_digest(respond(kp, challenge), response)
