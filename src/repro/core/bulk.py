"""Bulk-transfer plane: adaptive parallel streams over the event engine.

XUFS's headline claim is wide-area throughput competitive with
high-performance file systems, but a fixed ≤12-stream pool leaves a
high bandwidth-delay-product link mostly idle: 12 window-limited TCP
streams of ``per_stream_bw`` each cap the pair at
``12 x per_stream_bw`` no matter how fat the link is.  Following the
GridFTP line (Allcock et al.) and xDFS (Poshtkohi et al.), this module
makes the stream count a *per-transfer decision*:

  * :func:`grant_streams` — the static budget.  The number of
    window-limited streams needed to fill the path is the
    bandwidth-delay product over the per-stream window,

        n* = ceil(BDP / per-stream window)
           = ceil((latency x path_bw) / (latency x per_stream_bw))
           = ceil(path_bw / per_stream_bw)

    where ``path_bw`` is the link bandwidth clamped by any NIC budget
    at either endpoint (streams beyond a NIC cap buy nothing).  The
    grant is further clamped to the payload (one stream per
    ``MIN_STREAM_BYTES``) and to the spec's ``[min_streams,
    max_streams]`` window.  With ``adapt=False`` the derivation is
    skipped entirely and the grant is the fixed ``max_streams``
    (payload-clamped) — a *fixed-width plan*, the mode whose traces are
    provably bit-identical to the legacy 12-stream constant when
    ``max_streams == 12`` (``tests/test_bulk.py``).
  * :class:`BulkTransfer` — the AIMD executor.  A payload moves in
    *waves* of ``width x probe_bytes`` striped through ONE
    :meth:`~repro.core.transport.Network.transfer_batch` reservation
    batch; after each wave the achieved throughput (wave bytes over
    wave elapsed on the virtual clock) feeds the congestion-control
    rule: **additive increase** (``+grow_step``) while a wave improves
    on the best observed throughput by more than
    ``improve_threshold``, **multiplicative decrease** (``x backoff``)
    when a wave degrades against the previous one by more than
    ``degrade_threshold`` — NIC backlog from competing traffic is
    exactly what stretches a wave's completion, so the width follows
    the congestion state the static grant cannot see.  The first wave
    starts at the granted n*, not at 1: the static budget seeds the
    search, adaptation only corrects it.
  * :func:`ensure_channel_width` — a granted width beyond
    ``Network.channels_per_pair`` raises the pool (the engine pads
    idle channel columns; ``transport.py`` supports raising the width
    mid-run, never lowering it).

Gating: everything here is opt-in.  A :class:`BulkSpec` reaches the
fabric via ``ReplicaPolicy(bulk=...)`` / ``FabricSpec(bulk=...)``
(``docs/fabric.md``); with the spec unset, striping keeps the fixed
12-stream constant, repair sources stay as they were, and every trace
is bit-identical to the pre-bulk engine (``benchmarks/fig_bulk.py``
gates this).  ``third_party`` additionally lets the replica fabric
move maintenance bytes directly between storage endpoints
(replica→replica) instead of through the client's NIC — the selection
itself lives in ``repro.core.replication`` (``docs/maintenance.md``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

from repro.core.transport import KB, MB, Network

#: One stream per this many payload bytes at most — matches striping's
#: ``MIN_BLOCK`` so a granted plan never stripes below the legacy block.
MIN_STREAM_BYTES = 64 * KB


@dataclass(frozen=True)
class BulkSpec:
    """Declarative bulk-transfer policy (frozen, validates on build).

    ``min_streams``/``max_streams`` bound every granted width.
    ``probe_bytes`` is the per-stream wave size the AIMD loop probes
    with (a wave moves ``width x probe_bytes``); waves shorter than the
    path's BDP amortize latency poorly, so size it at least
    ``latency x per_stream_bw``.  ``adapt=False`` freezes the width at
    ``max_streams`` (payload-clamped) and moves the payload in one
    wave — the fixed-width mode whose plans are bit-identical to the
    legacy constant when ``max_streams == 12``.  ``third_party``
    gates replica→replica maintenance movement
    (:meth:`repro.core.replication.ReplicaSet.third_party_source`).
    """

    min_streams: int = 1
    max_streams: int = 64
    probe_bytes: int = 16 * MB
    adapt: bool = True
    third_party: bool = True
    grow_step: int = 4
    backoff: float = 0.5
    improve_threshold: float = 0.05
    degrade_threshold: float = 0.15

    def __post_init__(self) -> None:
        if self.min_streams < 1:
            raise ValueError(
                f"min_streams must be >= 1: {self.min_streams}")
        if self.max_streams < self.min_streams:
            raise ValueError(
                f"max_streams ({self.max_streams}) < min_streams "
                f"({self.min_streams})")
        if self.probe_bytes <= 0:
            raise ValueError(
                f"probe_bytes must be > 0: {self.probe_bytes}")
        if self.grow_step < 1:
            raise ValueError(f"grow_step must be >= 1: {self.grow_step}")
        if not (0.0 < self.backoff < 1.0):
            raise ValueError(
                f"backoff must be in (0, 1): {self.backoff}")
        if self.improve_threshold < 0 or self.degrade_threshold < 0:
            raise ValueError(
                "improve/degrade thresholds must be >= 0: "
                f"{self.improve_threshold}, {self.degrade_threshold}")


def grant_streams(network: Network, src: str, dst: str, nbytes: int,
                  spec: BulkSpec) -> int:
    """Stream budget for one ``src -> dst`` transfer of ``nbytes``.

    ``adapt=True``: the BDP-derived fill count ``ceil(path_bw /
    per_stream_bw)`` with ``path_bw`` NIC-clamped, bounded by the
    payload and the spec window.  ``adapt=False``: the fixed
    ``max_streams`` (payload-clamped) — no derivation, so the grant
    cannot depend on budgets or link shape (the fixed-width identity
    mode).
    """
    chunks = max(1, nbytes // MIN_STREAM_BYTES) if nbytes > 0 else 1
    if not spec.adapt:
        width = min(spec.max_streams, chunks)
    else:
        link = network.link_between(src, dst)
        path_bw = link.link_bw
        for ep in (src, dst):
            b = network.nic_budget(ep)
            if b is not None and b < path_bw:
                path_bw = b
        fill = max(1, -(-int(path_bw) // max(int(link.per_stream_bw), 1)))
        width = min(spec.max_streams, fill, chunks)
    return max(spec.min_streams, width)


def ensure_channel_width(network: Network, width: int) -> None:
    """Raise the per-pair channel pool to carry ``width`` concurrent
    streams.  Raising pads idle columns (indistinguishable from
    never-used channels — the regression test in ``tests/test_bulk.py``
    holds this); lowering mid-run is unsupported and never attempted."""
    if width > int(network.channels_per_pair):
        network.channels_per_pair = int(width)


@dataclass(frozen=True)
class BulkResult:
    """Outcome of one bulk push: the figure-of-merit record the
    benchmark reports (virtual-clock elapsed, per-wave width history,
    achieved throughput)."""

    src: str
    dst: str
    nbytes: int
    elapsed_s: float
    waves: int
    widths: Tuple[int, ...]
    throughput_bps: float


class BulkTransfer:
    """AIMD bulk mover: waves of parallel streams sized by observables.

    Each wave is one ``transfer_batch`` reservation batch of ``width``
    same-pair stripes (``concurrency=width``, so each stream holds a
    window-limited ``link_bw / width`` share at most), waited to
    completion before the next wave is sized — the wait IS the
    throughput probe.  ``push`` works on sizes (checkpoint-scale
    transfers should not materialize gigabytes); ``send`` wraps real
    payload bytes.
    """

    def __init__(self, network: Network,
                 spec: Optional[BulkSpec] = None):
        self.network = network
        self.spec = spec if spec is not None else BulkSpec()

    def grant(self, src: str, dst: str, nbytes: int) -> int:
        return grant_streams(self.network, src, dst, nbytes, self.spec)

    def push(self, src: str, dst: str, nbytes: int, *,
             method: str = "bulk",
             wave_cb: Optional[Callable[[int, int, int, float], None]]
             = None) -> BulkResult:
        """Move ``nbytes`` from ``src`` to ``dst``; the clock advances
        to the last wave's completion.  ``wave_cb(wave_index, width,
        wave_bytes, wave_elapsed_s)`` observes each wave (progress
        reporting; tests use it to inject competing traffic between
        waves)."""
        net = self.network
        spec = self.spec
        t0 = net.clock
        if nbytes <= 0:
            return BulkResult(src=src, dst=dst, nbytes=0, elapsed_s=0.0,
                              waves=0, widths=(), throughput_bps=0.0)
        width = self.grant(src, dst, nbytes)
        ensure_channel_width(net, min(spec.max_streams, width))
        widths = []
        sent = 0
        best_tput = 0.0
        prev_tput: Optional[float] = None
        while sent < nbytes:
            remaining = nbytes - sent
            w = max(1, min(width, max(1, remaining // MIN_STREAM_BYTES)))
            ensure_channel_width(net, w)
            chunk = min(remaining, w * spec.probe_bytes) if spec.adapt \
                else remaining
            base = chunk // w
            lens = [base] * (w - 1) + [chunk - base * (w - 1)]
            wave_t0 = net.clock
            batch = net.transfer_batch(
                [(src, dst, method, ln, w, False, 0.0) for ln in lens])
            net.wait_batch(batch)
            dt = net.clock - wave_t0
            tput = chunk / dt if dt > 0 else float("inf")
            widths.append(w)
            sent += chunk
            if wave_cb is not None:
                wave_cb(len(widths) - 1, w, chunk, dt)
            if spec.adapt and sent < nbytes:
                if prev_tput is not None and \
                        tput < prev_tput * (1.0 - spec.degrade_threshold):
                    # congestion: a wave lost ground against the last
                    # one (NIC backlog stretched its completion) —
                    # multiplicative decrease
                    width = max(spec.min_streams,
                                int(width * spec.backoff))
                elif tput > best_tput * (1.0 + spec.improve_threshold):
                    # still improving on the best observed: additive
                    # increase, up to the spec ceiling
                    width = min(spec.max_streams, width + spec.grow_step)
                if tput > best_tput:
                    best_tput = tput
                prev_tput = tput
        elapsed = net.clock - t0
        return BulkResult(
            src=src, dst=dst, nbytes=nbytes, elapsed_s=elapsed,
            waves=len(widths), widths=tuple(widths),
            throughput_bps=nbytes / elapsed if elapsed > 0 else 0.0)

    def send(self, src: str, dst: str, payload: bytes, *,
             method: str = "bulk") -> BulkResult:
        """Blocking transfer of real payload bytes (``push`` on the
        payload's size — the wire model only prices sizes)."""
        return self.push(src, dst, len(payload), method=method)
