"""USSH session (paper §3.2): login, per-user file server, authenticated mount.

``ussh_login`` mirrors the paper's flow: generate a short-lived
<key, phrase>, start a personal user-space file server at the home
endpoint, authenticate the remote side via the HMAC challenge, and return
a client whose mounts ride the authenticated token.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Dict, List, Optional

from repro.core.namespace import XufsClient
from repro.core.replication import ReplicaSet, WritePolicy
from repro.core.store import HomeStore
from repro.core.transport import (
    AuthError, Endpoint, KeyPhrase, Network, respond,
)


@dataclass
class UserFileServer:
    """Personal user-space file server bound to one user's home space."""

    user: str
    endpoint: Endpoint
    store: HomeStore
    restarts: int = 0

    def crash(self) -> None:
        """Simulate a server crash: drop auth state + subscriptions."""
        self.store._authed_tokens.clear()
        self.store._subscribers.clear()

    def restart(self) -> None:
        """The paper restarts the server from a crontab job on recovery."""
        self.restarts += 1


@dataclass
class Session:
    user: str
    network: Network
    server: UserFileServer
    client: XufsClient
    token: str
    replicas: Optional[ReplicaSet] = None

    def remount(self, prefix: str, localized: Optional[List[str]] = None):
        token = _authenticate(self.server)
        self.token = token
        if self.replicas is not None:
            self.replicas.reattach(token=token)
        self.client.mount(prefix, self.server.endpoint.name,
                          self.server.store, token,
                          localized=localized, replicas=self.replicas)


def _authenticate(server: UserFileServer) -> str:
    kp = server.store.keyphrase
    return server.store.authenticate(lambda ch: respond(kp, ch))


def ussh_login(user: str, network: Network, home_root: str,
               site_root: str, *, home_name: str = "home",
               site_name: str = "site",
               mounts: Optional[Dict[str, List[str]]] = None,
               replica_sites: Optional[Dict[str, float]] = None,
               write_quorum: "WritePolicy" = 1,
               nic_budgets: Optional[Dict[str, float]] = None,
               queue_aware: bool = True) -> Session:
    """Login from the personal system into a site; mount the home space.

    ``mounts`` maps namespace prefix -> localized sub-prefixes.
    ``replica_sites`` maps replica endpoint name -> one-way latency (s)
    from the compute site; each named site gets a read replica of the
    home space registered in the session's :class:`ReplicaSet`, and cache
    fills route to the cheapest fresh replica.
    ``write_quorum`` sets the write-ack policy over home + replicas: an
    explicit W, or ``"majority"`` / ``"all"``.  The default (1) is the
    legacy policy — the home apply alone acks and fan-out is best-effort.
    ``nic_budgets`` maps endpoint name -> aggregate NIC bytes/s
    (``Network.set_nic_budget``); unlisted endpoints stay uncapped.
    ``queue_aware`` toggles estimated-completion routing on the replica
    set (False restores static nearest-by-latency ranking).
    """
    home_ep = Endpoint(home_name, network)
    Endpoint(site_name, network)
    for ep_name, budget in (nic_budgets or {}).items():
        network.set_nic_budget(ep_name, budget)
    kp = KeyPhrase.generate()
    store = HomeStore(os.path.join(home_root, user), endpoint=home_ep,
                      keyphrase=kp)
    server = UserFileServer(user=user, endpoint=home_ep, store=store)
    # SSH-authenticated login, then challenge-auth the data connections
    network.rpc(site_name, home_name, "ssh_login", encrypted=True)
    token = _authenticate(server)
    replicas: Optional[ReplicaSet] = None
    if replica_sites:
        replicas = ReplicaSet(network=network, home_name=home_name,
                              home_store=store, token=token,
                              write_quorum=write_quorum,
                              queue_aware=queue_aware)
        for rname, latency_s in replica_sites.items():
            rep_ep = Endpoint(rname, network)
            network.set_link(site_name, rname,
                             _dc_replace(network.link, latency_s=latency_s))
            # replica sites are near the compute site but WAN-far from
            # home: model the home<->replica path through the site region,
            # so fan-out applies to different replicas finish at distinct
            # times (what makes W<N drain time beat W=all under overlap)
            network.set_link(home_name, rname,
                             _dc_replace(network.link,
                                         latency_s=network.link.latency_s +
                                         latency_s))
            rstore = HomeStore(
                os.path.join(home_root, ".replicas", rname, user),
                endpoint=rep_ep)
            replicas.add_replica(rname, rstore)
    client = XufsClient(site_name, network,
                        cache_root=os.path.join(site_root, user, "cache"),
                        oplog_root=os.path.join(site_root, user, "oplog"),
                        owner=user)
    for prefix, localized in (mounts or {"home/": []}).items():
        client.mount(prefix, home_name, store, token, localized=localized,
                     replicas=replicas)
    return Session(user=user, network=network, server=server, client=client,
                   token=token, replicas=replicas)
