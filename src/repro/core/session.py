"""USSH session objects + the deprecated ``ussh_login`` shim.

:class:`Session` is what :meth:`repro.core.fabric.Fabric.login` returns:
the user's personal file server, the site-side client, the auth token,
and the replica fabric, plus the :class:`~repro.core.fabric.MountSpec`
per mount so a bare :meth:`Session.remount` restores every mount exactly
as declared (localized sub-prefixes included).

``ussh_login`` mirrors the paper's §3.2 flow but is **deprecated**: it
accreted ten keyword arguments and hid link construction, latency
composition, and NIC wiring in its body.  It survives as a thin shim
that assembles a declarative :class:`~repro.core.fabric.FabricSpec` and
delegates to ``Fabric.login`` — bit-identical wiring (held by
``tests/test_fabric_spec.py``), one :class:`DeprecationWarning` per
process.  New code declares a spec; see ``docs/fabric.md``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, List, Optional

from repro.core.namespace import XufsClient
from repro.core.replication import ReplicaSet, WritePolicy
from repro.core.store import HomeStore
from repro.core.transport import Endpoint, Network, respond

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.core.fabric import MountSpec
    from repro.core.tasks import MaintenanceReport, MaintenanceScheduler


@dataclass
class UserFileServer:
    """Personal user-space file server bound to one user's home space."""

    user: str
    endpoint: Endpoint
    store: HomeStore
    restarts: int = 0

    def crash(self) -> None:
        """Simulate a server crash: drop auth state + subscriptions."""
        self.store._authed_tokens.clear()
        self.store._subscribers.clear()

    def restart(self) -> None:
        """The paper restarts the server from a crontab job on recovery."""
        self.restarts += 1


@dataclass
class Session:
    user: str
    network: Network
    server: UserFileServer
    client: XufsClient
    token: str
    replicas: Optional[ReplicaSet] = None
    #: prefix -> the MountSpec it was mounted with; remount()'s witness.
    mount_specs: Dict[str, "MountSpec"] = field(default_factory=dict)
    #: the Fabric's shared maintenance scheduler (None when the spec
    #: declared no MaintenanceSpec) — the session's handle for driving
    #: background upkeep (``scheduler.run_until``) and inspecting it.
    scheduler: Optional["MaintenanceScheduler"] = None

    def maintenance_report(self) -> Optional["MaintenanceReport"]:
        """Snapshot of the fabric's maintenance plane, or None when no
        ``MaintenanceSpec`` was declared."""
        return self.scheduler.report() if self.scheduler is not None \
            else None

    def remount(self, prefix: Optional[str] = None,
                localized: Optional[List[str]] = None) -> None:
        """Re-authenticate and re-mount this session's home mounts.

        With no arguments every mount backed by this session's home
        store is restored exactly as declared — stored
        :class:`MountSpec` first, mounts added directly via
        ``client.mount()`` field-for-field off the live Mount (localized
        sub-prefixes included either way; a bare remount used to
        silently drop them).  Mounts backed by a *foreign* home store
        are left untouched: our crash did not invalidate their tokens
        and this session cannot re-authenticate them.  ``prefix``
        restores one mount; ``localized`` additionally replaces that
        mount's localized set and updates the stored spec.  All
        argument validation happens before the token rotates, so a
        rejected call leaves the session exactly as it was.
        """
        from repro.core.fabric import MountSpec   # session<->fabric cycle
        if prefix is None and localized is not None:
            raise ValueError("localized override requires a prefix")
        target: Optional["MountSpec"] = None
        if prefix is not None:
            live = self.client.mounts.get(prefix)
            if live is not None and live.store is not self.server.store:
                raise ValueError(
                    f"mount {prefix!r} is backed by another home store; "
                    "remount it from the session that owns it")
            if localized is not None:
                target = MountSpec(prefix, tuple(localized))
            elif prefix in self.mount_specs:
                target = self.mount_specs[prefix]
            else:
                try:
                    target = MountSpec(prefix, tuple(live.localized)
                                       if live is not None else ())
                except ValueError:
                    target = None     # legacy spelling client.mount()
                    #                   accepted: restore raw, unrecorded
        token = _authenticate(self.server)
        self.token = token
        if self.replicas is not None:
            self.replicas.reattach(token=token)
        if prefix is not None:
            if target is not None:
                self.mount_specs[prefix] = target
                loc = list(target.localized)
            else:
                loc = list(live.localized) if live is not None else []
            # a live mount keeps its own replica wiring (a side mount
            # created replicas=None must not gain the session's fabric)
            self.client.mount(prefix, self.server.endpoint.name,
                              self.server.store, token, localized=loc,
                              replicas=live.replicas if live is not None
                              else self.replicas)
            return
        for spec in self.mount_specs.values():
            live = self.client.mounts.get(spec.prefix)
            if live is not None and live.store is not self.server.store:
                continue          # prefix re-pointed at a foreign home
                #                   since login: the live mount wins
            self.client.mount(spec.prefix, self.server.endpoint.name,
                              self.server.store, token,
                              localized=list(spec.localized),
                              replicas=live.replicas if live is not None
                              else self.replicas)
        for p, m in list(self.client.mounts.items()):
            if p in self.mount_specs or m.store is not self.server.store:
                continue          # foreign home: not ours to rebind
            self.client.mount(p, m.server_name, m.store, token,
                              localized=list(m.localized),
                              replicas=m.replicas)


def _authenticate(server: UserFileServer) -> str:
    kp = server.store.keyphrase
    return server.store.authenticate(lambda ch: respond(kp, ch))


#: ``ussh_login`` warns once per process, not once per call — benchmark
#: sweeps and multi-user scripts log in dozens of times.
_DEPRECATION_WARNED = False


def ussh_login(user: str, network: Network, home_root: str,
               site_root: str, *, home_name: str = "home",
               site_name: str = "site",
               mounts: Optional[Dict[str, List[str]]] = None,
               replica_sites: Optional[Dict[str, float]] = None,
               write_quorum: "WritePolicy" = 1,
               nic_budgets: Optional[Dict[str, float]] = None,
               queue_aware: bool = True) -> Session:
    """Deprecated: assemble a :class:`FabricSpec` and ``Fabric.login``.

    Kept as a shim for existing callers; the wiring is bit-identical to
    the spec path (``tests/test_fabric_spec.py`` holds the trace
    equivalence).  The keyword arguments map onto the spec one-for-one —
    ``docs/fabric.md`` has the full migration table:

    ``home_name``/``site_name`` + roots -> :class:`SiteSpec`;
    ``replica_sites={r: lat}`` -> ``SiteSpec(r)`` + ``LinkSpec(site, r,
    latency_s=lat)`` + ``ReplicaPolicy(sites=(r, ...))``;
    ``write_quorum``/``queue_aware`` -> :class:`ReplicaPolicy` fields;
    ``nic_budgets`` -> ``SiteSpec(nic_budget=...)``;
    ``mounts={prefix: localized}`` -> :class:`MountSpec`.

    One deliberate tightening: mount prefixes not ending in ``/`` (or
    localized entries outside their prefix) now fail fast with
    ``ValueError`` via :class:`MountSpec` validation, where the old code
    silently accepted them and string-prefix matching could bleed a
    ``data`` mount onto ``database/...`` paths.
    """
    from repro.core.fabric import (
        Fabric, FabricSpec, MountSpec, ReplicaPolicy,
    )
    global _DEPRECATION_WARNED
    if not _DEPRECATION_WARNED:
        _DEPRECATION_WARNED = True
        warnings.warn(
            "ussh_login() is deprecated: declare the topology once — "
            "Fabric(FabricSpec(sites=(SiteSpec('home', root=...), "
            "SiteSpec('site', root=...), ...), links=(LinkSpec('site', "
            "'r1', latency_s=...), ...))).login(user, "
            "mounts=[MountSpec('home/', localized=(...,))], "
            "replicas=ReplicaPolicy(sites=(...), write_quorum=..., "
            "queue_aware=...)) — see docs/fabric.md for the migration "
            "table", DeprecationWarning, stacklevel=2)
    spec = FabricSpec.star(home_root, site_root, home=home_name,
                           site=site_name, replica_latencies=replica_sites,
                           nic_budgets=nic_budgets, link=network.link)
    policy = None
    if replica_sites:
        policy = ReplicaPolicy(sites=tuple(replica_sites),
                               write_quorum=write_quorum,
                               queue_aware=queue_aware)
    # an empty mounts dict got the default home/ mount pre-refactor
    # (`mounts or {...}`) — only a non-empty dict overrides it
    mount_specs = [MountSpec(prefix, tuple(localized or ()))
                   for prefix, localized in mounts.items()] \
        if mounts else None
    return Fabric(spec, network=network).login(
        user, home=home_name, site=site_name, mounts=mount_specs,
        replicas=policy)
