"""Background maintenance plane: scheduled fabric upkeep on the virtual
clock, with retry, backoff, per-path locks, and a dead-letter record.

XUFS's disconnection machinery — anti-entropy ``resync()``, read-repair
drain, lease ``renew_all()``, oplog ``reconcile()`` — used to run inline
from whatever client call happened to trigger it, so maintenance cost
rode reader latency and a partition mid-renewal silently corrupted lease
state.  This module makes that work a first-class subsystem, following
the GridFTP replica-management line (Allcock et al.) and the xDFS
transfer framework (Poshtkohi et al.): reliable retry-driven background
movement instead of a side effect of foreground I/O.

  * :class:`MaintenanceSpec` — the declarative knob on
    :class:`~repro.core.fabric.FabricSpec`: task periods, the
    :class:`RetryPolicy`, and the per-path lock lease.  Unset ⇒ no
    scheduler exists and every wire event is bit-identical to the
    pre-maintenance fabric (the benchmark gate).
  * :class:`MaintenanceScheduler` — owned by one
    :class:`~repro.core.fabric.Fabric` and shared by ALL its logins.
    Driven entirely by the transport's per-channel virtual clock
    (``Network.clock``): :meth:`tick` runs everything due *now*,
    :meth:`run_until` walks the clock from due-time to due-time.  No
    wall time, no jitter — same schedule ⇒ same trace.
  * :class:`RetryPolicy` — deterministic exponential backoff.  A task
    that raises is retried at ``base * multiplier^k`` delays (capped);
    after ``max_retries`` consecutive failures it is **dead-lettered**:
    removed from the schedule and recorded (attempts, backoff history,
    error strings, timestamps) for operators/benchmarks to inspect via
    :meth:`MaintenanceScheduler.report`.  :meth:`revive` puts a dead
    task back on the schedule once the fault is fixed.
  * :class:`LockTable` — per-path leases over the shared fabric so two
    sessions attached to one replica set never double-repair the same
    path.  Locks expire on the virtual clock (release is itself a WAN
    round-trip in a real deployment, so the conservative crash-safe
    default is to let the lease lapse); re-acquire by the same owner
    extends.  Conflicts are counted, not blocked on.

Counters (``tasks_run``, ``retries``, ``dead_lettered``,
``lock_conflicts``, ``repairs``, ``double_repairs``, ``evictions``)
plus per-task stats
snapshot into a :class:`MaintenanceReport` — what
``benchmarks/fig_maintenance.py`` gates on.  See ``docs/maintenance.md``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Dict, List, Optional, Tuple

from repro.core.transport import Network

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.core.replication import PendingApply, ReplicaSet


@dataclass(frozen=True)
class RetryPolicy:
    """Deterministic exponential backoff — jitter-free on purpose: the
    virtual clock is the determinism witness, so retry ``k`` of a failing
    task always lands at ``base_delay_s * multiplier**(k-1)`` (capped at
    ``max_delay_s``) after the failure.  ``max_retries`` consecutive
    failures dead-letter the task."""

    max_retries: int = 3
    base_delay_s: float = 1.0
    multiplier: float = 2.0
    max_delay_s: float = 60.0

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0: {self.max_retries}")
        if self.base_delay_s <= 0:
            raise ValueError(
                f"base_delay_s must be > 0: {self.base_delay_s}")
        if self.multiplier < 1.0:
            raise ValueError(
                f"multiplier must be >= 1 (backoff never shrinks): "
                f"{self.multiplier}")
        if self.max_delay_s < self.base_delay_s:
            raise ValueError(
                f"max_delay_s ({self.max_delay_s}) < base_delay_s "
                f"({self.base_delay_s})")

    def delay_s(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return min(self.base_delay_s * self.multiplier ** (attempt - 1),
                   self.max_delay_s)


@dataclass(frozen=True)
class MaintenanceSpec:
    """Declarative maintenance plane: periods for the four scheduled
    task families, the retry policy, and the per-path repair-lock lease.
    Attach to :class:`~repro.core.fabric.FabricSpec` (``maintenance=``);
    leaving it unset keeps the fabric scheduler-free and every trace
    bit-identical to the pre-maintenance code."""

    resync_period_s: float = 30.0
    repair_period_s: float = 5.0
    lease_period_s: float = 10.0
    reconcile_period_s: float = 15.0
    retry: RetryPolicy = field(default_factory=RetryPolicy)
    lock_lease_s: float = 30.0

    def __post_init__(self) -> None:
        for name in ("resync_period_s", "repair_period_s",
                     "lease_period_s", "reconcile_period_s",
                     "lock_lease_s"):
            v = getattr(self, name)
            if v <= 0:
                raise ValueError(f"{name} must be > 0: {v}")


@dataclass(frozen=True)
class DeadLetter:
    """One task the scheduler gave up on: the inspectable record of a
    failure episode that outlived its retry budget."""

    task: str
    owner: str
    attempts: int                    # failed executions (initial + retries)
    backoff_s: Tuple[float, ...]     # the delays actually scheduled
    errors: Tuple[str, ...]          # one per failed execution
    first_failed_at: float
    dead_at: float


@dataclass
class ConflictRecord:
    """One detected concurrent-writer divergence (sibling of
    :class:`DeadLetter`): two vector-timestamp branches of the same path
    that neither dominates the other.  Reconciliation auto-picks a
    deterministic last-writer-wins ``winner`` and lands its bytes at
    home, but the losing branch is preserved here — a true conflict is
    never silently clobbered.  ``resolve()`` lets an operator override
    the automatic pick by re-applying either branch on top."""

    path: str
    seq: int                         # oplog seq of the detecting record
    owner: str                       # writer whose reconcile detected it
    ours_vts: Dict[str, int]         # the reconciling record's stamp
    theirs_vts: Dict[str, int]       # home's frontier at detection
    winner: str                      # "ours" | "theirs" (LWW auto-pick)
    ours_data: bytes
    theirs_data: bytes
    detected_at: float
    resolved: bool = False
    resolution: Optional[str] = None
    _apply: Optional[Callable[[bytes], None]] = field(
        default=None, repr=False, compare=False)

    def resolve(self, keep: str) -> None:
        """Operator override: re-apply the chosen branch (``"ours"`` or
        ``"theirs"``) on top at home.  One-shot."""
        if keep not in ("ours", "theirs"):
            raise ValueError(f'resolve() takes "ours" or "theirs": {keep!r}')
        if self.resolved:
            raise RuntimeError(
                f"conflict on {self.path!r} already resolved "
                f"({self.resolution})")
        if self._apply is not None:
            self._apply(self.ours_data if keep == "ours"
                        else self.theirs_data)
        self.resolved = True
        self.resolution = keep


@dataclass
class ScheduledTask:
    """One periodic schedule entry.  ``fn`` returning normally is
    success; raising is a failure that enters the retry/backoff ladder.
    State is per-failure-episode: success resets it."""

    name: str
    owner: str
    fn: Callable[[], object]
    period_s: float
    retry: RetryPolicy
    next_due: float
    runs: int = 0
    failures: int = 0
    attempt: int = 0                 # retries scheduled this episode
    backoff_s: List[float] = field(default_factory=list)
    errors: List[str] = field(default_factory=list)
    first_failed_at: Optional[float] = None
    dead: bool = False
    last_result: object = None


class LockTable:
    """Per-path lease locks over one fabric's shared state.

    ``acquire`` grants (or same-owner-extends) a lease until
    ``now + lease_s``; a different owner before expiry is a counted
    conflict.  There is no blocking: maintenance that loses the race
    simply skips the path this tick — the holder (or the next tick)
    covers it.  Expiry is judged on the caller-supplied virtual clock,
    so lock lifetime is deterministic.
    """

    def __init__(self, lease_s: float):
        if lease_s <= 0:
            raise ValueError(f"lock lease must be > 0: {lease_s}")
        self.lease_s = lease_s
        self._locks: Dict[str, Tuple[str, float]] = {}
        self.acquired = 0
        self.conflicts = 0

    def holder(self, key: str, now: float) -> Optional[str]:
        cur = self._locks.get(key)
        if cur is None or cur[1] <= now:
            return None
        return cur[0]

    def acquire(self, key: str, owner: str, now: float) -> bool:
        cur = self._locks.get(key)
        if cur is not None and cur[1] > now and cur[0] != owner:
            self.conflicts += 1
            return False
        self._locks[key] = (owner, now + self.lease_s)
        self.acquired += 1
        return True

    def release(self, key: str, owner: str) -> None:
        cur = self._locks.get(key)
        if cur is not None and cur[0] == owner:
            del self._locks[key]


@dataclass(frozen=True)
class MaintenanceReport:
    """Point-in-time snapshot the benchmarks gate on."""

    clock: float
    tasks_run: int
    retries: int
    dead_lettered: int
    lock_conflicts: int
    repairs: int
    double_repairs: int
    evictions: int
    conflicts: int
    #: replica-apply payload bytes by provenance (Network counters):
    #: third-party = storage->storage movement, client-mediated = pushed
    #: off a client session's NIC — the bulk plane's offload witness
    bytes_third_party: int
    bytes_client_mediated: int
    inflight: int
    #: task name -> {owner, runs, failures, attempt, next_due, dead}
    tasks: Dict[str, Dict[str, object]]
    dead_letters: Tuple[DeadLetter, ...]
    conflict_records: Tuple[ConflictRecord, ...]


class MaintenanceScheduler:
    """Periodic maintenance on the virtual clock, one per Fabric.

    All sessions logging into (or attaching to) a fabric register their
    task closures here, so the whole fabric's upkeep is schedulable,
    observable, and throttleable in one place.  The scheduler never
    advances the clock on its own except through :meth:`run_until`
    (walking due-time to due-time) and whatever waits the tasks
    themselves perform; :meth:`tick` at a fixed clock is side-effect-free
    when nothing is due.
    """

    #: hard ceiling on run_until iterations — a misconfigured period
    #: must fail loudly, not spin the simulator forever
    MAX_EVENTS = 1_000_000

    def __init__(self, network: Network, spec: MaintenanceSpec):
        self.network = network
        self.spec = spec
        self.tasks: Dict[str, ScheduledTask] = {}
        self.locks = LockTable(spec.lock_lease_s)
        self.dead_letters: List[DeadLetter] = []
        self.tasks_run = 0
        self.retries = 0
        self.dead_lettered = 0
        self.repairs = 0
        self.double_repairs = 0
        self.evictions = 0
        # concurrent-writer divergences surfaced by client reconciles
        self.conflicts: List[ConflictRecord] = []
        # armed FaultInjector (see Fabric.arm_faults): run_until walks
        # the clock to scheduled fault times even when no task is due
        self.faults: Optional[object] = None
        # repairs launched but not yet acked: (replica set, pending apply)
        self._inflight: List[Tuple["ReplicaSet", "PendingApply"]] = []
        self._tick_seq = 0
        # path -> (tick seq, owner) of the latest repair launch; a second
        # owner launching for the same path in the same tick IS the
        # double-repair the per-path locks exist to prevent
        self._repair_marks: Dict[str, Tuple[int, str]] = {}
        # stable per-process keys for replica sets ("rs0", "rs1", ...):
        # lock keys must be deterministic across sessions sharing a set
        self._rset_keys: Dict[int, str] = {}

    # ---- registration ----------------------------------------------------
    def register(self, name: str, fn: Callable[[], object], *,
                 period_s: float, owner: str = "fabric",
                 retry: Optional[RetryPolicy] = None,
                 first_due: Optional[float] = None) -> ScheduledTask:
        """Add one periodic task.  First run lands one period from now
        unless ``first_due`` pins it.  Registration touches no wire —
        a fabric with a scheduler but no ticks traces identically to a
        fabric without one."""
        if name in self.tasks:
            raise ValueError(f"task {name!r} already registered")
        if period_s <= 0:
            raise ValueError(f"task {name!r}: period must be > 0: "
                             f"{period_s}")
        t = ScheduledTask(
            name=name, owner=owner, fn=fn, period_s=period_s,
            retry=retry if retry is not None else self.spec.retry,
            next_due=(first_due if first_due is not None
                      else self.network.clock + period_s))
        self.tasks[name] = t
        return t

    def rset_key(self, rset: "ReplicaSet") -> str:
        """Stable lock-key prefix for a replica set shared by multiple
        sessions (first registration wins the name)."""
        key = self._rset_keys.get(id(rset))
        if key is None:
            key = f"rs{len(self._rset_keys)}"
            self._rset_keys[id(rset)] = key
        return key

    # ---- repair bookkeeping ----------------------------------------------
    def note_repair(self, path_key: str, owner: str) -> None:
        """Record a repair launch; flags a double repair when another
        owner launched for the same path in the same tick."""
        mark = self._repair_marks.get(path_key)
        if (mark is not None and mark[0] == self._tick_seq
                and mark[1] != owner):
            self.double_repairs += 1
        self._repair_marks[path_key] = (self._tick_seq, owner)
        self.repairs += 1

    def note_conflict(self, record: ConflictRecord) -> None:
        """Adopt a concurrent-writer conflict detected by a client's
        reconcile (wired up by the fabric) so it surfaces in
        :meth:`report` next to the dead letters."""
        self.conflicts.append(record)

    def track(self, rset: "ReplicaSet",
              pending: List["PendingApply"]) -> None:
        """Adopt launched-but-unacked repair applies; they land (bytes
        into the replica store, catalog updated, lag cleared) at the
        first tick whose clock has passed their ack."""
        for p in pending:
            self._inflight.append((rset, p))

    def _settle_inflight(self) -> int:
        now = self.network.clock
        landed = 0
        still: List[Tuple["ReplicaSet", "PendingApply"]] = []
        for rset, p in self._inflight:
            if p.ack.completion <= now:
                rset.complete_apply(p)
                landed += 1
            else:
                still.append((rset, p))
        self._inflight = still
        return landed

    def quiesce(self) -> int:
        """Wait out and land every in-flight repair (shutdown / report
        boundaries). Returns how many applies landed."""
        if not self._inflight:
            return 0
        self.network.wait_all([p.ack for _, p in self._inflight])
        return self._settle_inflight()

    # ---- the clock loop --------------------------------------------------
    @property
    def lock_conflicts(self) -> int:
        return self.locks.conflicts

    def next_event(self) -> Optional[float]:
        """Earliest virtual time anything needs attention: a task coming
        due or an in-flight repair ack landing."""
        times = [t.next_due for t in self.tasks.values() if not t.dead]
        times += [p.ack.completion for _, p in self._inflight]
        if self.faults is not None:
            nxt = self.faults.next_at()
            if nxt is not None:
                times.append(nxt)
        return min(times) if times else None

    def tick(self) -> int:
        """Run every task due at the current clock (registration order —
        deterministic), firing due fault-plan events and landing matured
        repair acks first.  Returns how many tasks ran."""
        self._tick_seq += 1
        if self.faults is not None:
            self.faults.advance_to(self.network.clock)
        self._settle_inflight()
        ran = 0
        now = self.network.clock
        for t in list(self.tasks.values()):
            if t.dead or t.next_due > now:
                continue
            self._run(t)
            ran += 1
        return ran

    def run_until(self, t_stop: float, *,
                  advance_to_stop: bool = True) -> float:
        """Walk the virtual clock forward to ``t_stop``, ticking at each
        due time.  This is how idle/think time hosts maintenance: the
        caller hands the scheduler a window and gets the clock back at
        ``t_stop`` with everything due inside it done (task-internal
        waits may push past a due time; later events catch up).
        """
        for _ in range(self.MAX_EVENTS):
            nxt = self.next_event()
            if nxt is None or nxt > t_stop:
                break
            if nxt > self.network.clock:
                self.network.advance(nxt - self.network.clock)
            self.tick()
        else:                                        # pragma: no cover
            raise RuntimeError("maintenance schedule did not converge "
                               f"within {self.MAX_EVENTS} events")
        if advance_to_stop and self.network.clock < t_stop:
            self.network.advance(t_stop - self.network.clock)
            self._settle_inflight()
        return self.network.clock

    # ---- execution / retry ladder ----------------------------------------
    def _run(self, t: ScheduledTask) -> None:
        self.tasks_run += 1
        t.runs += 1
        try:
            t.last_result = t.fn()
        except Exception as e:
            # scheduled upkeep must never crash the client: a failure
            # enters the retry ladder (or the dead-letter record), and
            # the session keeps serving reads/writes
            t.failures += 1
            if t.first_failed_at is None:
                t.first_failed_at = self.network.clock
            t.errors.append(f"{type(e).__name__}: {e}")
            if t.attempt >= t.retry.max_retries:
                self._dead_letter(t)
                return
            t.attempt += 1
            self.retries += 1
            delay = t.retry.delay_s(t.attempt)
            t.backoff_s.append(delay)
            t.next_due = self.network.clock + delay
            return
        # success closes the failure episode
        t.attempt = 0
        t.backoff_s.clear()
        t.errors.clear()
        t.first_failed_at = None
        t.next_due = self.network.clock + t.period_s

    def _dead_letter(self, t: ScheduledTask) -> None:
        t.dead = True
        self.dead_lettered += 1
        self.dead_letters.append(DeadLetter(
            task=t.name, owner=t.owner, attempts=t.attempt + 1,
            backoff_s=tuple(t.backoff_s), errors=tuple(t.errors),
            first_failed_at=t.first_failed_at if t.first_failed_at
            is not None else self.network.clock,
            dead_at=self.network.clock))

    def revive(self, name: str, *, delay_s: float = 0.0) -> ScheduledTask:
        """Dead-letter lifecycle, step 2: after the operator (or a heal)
        fixes the fault, put the task back on the schedule with a clean
        retry episode.  The dead-letter record itself is history — it
        stays in ``dead_letters``."""
        t = self.tasks[name]
        if t.dead:
            t.dead = False
            t.attempt = 0
            t.backoff_s = []
            t.errors = []
            t.first_failed_at = None
            t.next_due = self.network.clock + delay_s
        return t

    # ---- observability ---------------------------------------------------
    def report(self) -> MaintenanceReport:
        return MaintenanceReport(
            clock=self.network.clock,
            tasks_run=self.tasks_run,
            retries=self.retries,
            dead_lettered=self.dead_lettered,
            lock_conflicts=self.locks.conflicts,
            repairs=self.repairs,
            double_repairs=self.double_repairs,
            evictions=self.evictions,
            conflicts=len(self.conflicts),
            bytes_third_party=self.network.bytes_third_party,
            bytes_client_mediated=self.network.bytes_client_mediated,
            inflight=len(self._inflight),
            tasks={t.name: {
                "owner": t.owner, "runs": t.runs,
                "failures": t.failures, "attempt": t.attempt,
                "next_due": t.next_due, "dead": t.dead,
            } for t in self.tasks.values()},
            dead_letters=tuple(self.dead_letters),
            conflict_records=tuple(self.conflicts))
