"""XufsClient: the interposition seam (the paper's libxufs.so equivalent).

Applications (the trainer, the serving engine, the data pipeline) perform
all file access through this client.  Semantics per the paper:

  * ``opendir`` materializes the remote listing into cache space (hidden
    attribute files) and redirects directory ops locally;
  * first ``open`` of a file fetches the WHOLE object (striped);
  * mutating ops update the cache copy, append to the persisted meta-op
    queue, and return — nothing blocks on the WAN;
  * ``write`` accumulates in a shadow buffer; ``close`` enqueues one
    aggregated store op (**last-close-wins**);
  * callback invalidations mark entries stale; next access re-fetches;
  * *localized directories*: new data never ships back to home;
  * disconnected operation: reads serve from cache, writes queue.
"""
from __future__ import annotations

import heapq
import os
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cache import (
    CacheSpace, CacheEntry, EMPTY, VALID, DIRTY, INVALID,
)
from repro.core.callbacks import NotificationManager
from repro.core.lease import LeaseManager
from repro.core.oplog import (
    MetaOpQueue, OpRecord, vts_dominates, vts_lww_key, vts_merge,
)
from repro.core.replication import (
    ReadSource, ReplicaSet, WriteLeaseContended,
)
from repro.core.store import HomeStore, ObjectStat
from repro.core.striping import StripedTransfer
from repro.core.tasks import ConflictRecord
from repro.core.transport import (
    DisconnectedError, Network, QuorumNotReachedError,
)


@dataclass
class Mount:
    prefix: str                      # namespace prefix, e.g. "home/"
    server_name: str
    store: HomeStore
    token: str
    localized: List[str] = field(default_factory=list)
    replicas: Optional[ReplicaSet] = None

    def is_localized(self, path: str) -> bool:
        return any(path.startswith(ld) for ld in self.localized)


class XufsFile:
    """An open file handle over the cache copy + shadow write buffer."""

    def __init__(self, client: "XufsClient", path: str, mode: str):
        assert mode in ("r", "w", "a", "rw")
        self.client = client
        self.path = path
        self.mode = mode
        self.closed = False
        if "r" in mode or mode == "a":
            base = client._ensure_cached(path, create_ok="w" in mode or
                                         mode == "a")
        else:
            base = b""
        self._buf = bytearray(base if mode != "w" else b"")
        self._dirty = mode in ("w", "a")
        self._pos = len(self._buf) if mode == "a" else 0

    # ---- POSIX-ish surface -------------------------------------------------
    def read(self, n: int = -1) -> bytes:
        end = len(self._buf) if n < 0 else min(self._pos + n, len(self._buf))
        out = bytes(self._buf[self._pos:end])
        self._pos = end
        return out

    def write(self, data: bytes) -> int:
        end = self._pos + len(data)
        if end > len(self._buf):
            self._buf.extend(b"\x00" * (end - len(self._buf)))
        self._buf[self._pos:end] = data
        self._pos = end
        self._dirty = True
        return len(data)

    def seek(self, pos: int) -> None:
        self._pos = pos

    def close(self) -> None:
        """Update the cache copy; enqueue ONE aggregated store op."""
        if self.closed:
            return
        self.closed = True
        if self._dirty:
            self.client._close_write(self.path, bytes(self._buf))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class XufsClient:
    def __init__(self, name: str, network: Network, cache_root: str,
                 oplog_root: str, owner: str = "user"):
        self.name = name
        self.network = network
        self.cache = CacheSpace(cache_root)
        self.oplog = MetaOpQueue(oplog_root)
        self.transfer = StripedTransfer(network)
        self.mounts: Dict[str, Mount] = {}
        self.notifiers: Dict[str, NotificationManager] = {}
        self.leases: Dict[str, LeaseManager] = {}
        self.owner = owner
        self.cwd = ""
        #: op seq -> modeled WAN seconds from apply start to the W-th ack
        #: (most recent ACK_WINDOW ops; insertion order = seq order)
        self.ack_wan_s: Dict[int, float] = {}
        #: path -> causal frontier of this client's own stamped writes
        #: (covers successive disconnected writes whose fan-out never
        #: landed anywhere we can read the frontier back from)
        self._vts_frontier: Dict[str, Dict[str, int]] = {}
        #: concurrent-writer divergences this client's reconciles
        #: detected (every one also forwarded to ``_conflict_sink``)
        self.conflicts: List[ConflictRecord] = []
        #: fabric wiring: scheduler.note_conflict when maintenance is on
        self._conflict_sink: Optional[
            Callable[[ConflictRecord], None]] = None

    ACK_WINDOW = 1024

    def _note_ack(self, seq: int, wan_s: float) -> None:
        self.ack_wan_s[seq] = wan_s
        while len(self.ack_wan_s) > self.ACK_WINDOW:
            self.ack_wan_s.pop(next(iter(self.ack_wan_s)))

    # ---- mounts -----------------------------------------------------------
    def mount(self, prefix: str, server_name: str, store: HomeStore,
              token: str, localized: Optional[List[str]] = None,
              replicas: Optional[ReplicaSet] = None) -> Mount:
        m = Mount(prefix=prefix, server_name=server_name, store=store,
                  token=token, localized=localized or [],
                  replicas=replicas)
        if replicas is not None and replicas.bulk is not None \
                and self.transfer.spec is None:
            # bulk-plane opt-in rides the mount: the client's own striped
            # transfers (cache fills, flusher fan-out of large payloads)
            # size their stripe width from the granted stream budget
            self.transfer.spec = replicas.bulk
        self.mounts[prefix] = m
        old_nm = self.notifiers.get(prefix)
        if old_nm is not None:
            # re-mount (remount/recovery): drop the old channel's store
            # subscription, or every put() keeps feeding an orphaned
            # pending list nobody drains
            old_nm.teardown()
        nm = NotificationManager(self.network, self.name, server_name,
                                 store, self.cache, prefix=prefix)
        nm.register(token)
        self.notifiers[prefix] = nm
        lm = LeaseManager(
            self.network, self.name, server_name, store, owner=self.owner,
            token=token)
        old_lm = self.leases.get(prefix)
        if old_lm is not None and old_lm.store is store:
            # a re-mount rotates the token but must not forget which
            # locks this client believes it holds: carry them over AT
            # RISK — the server may have expired them while we were away
            # (crash/partition is why remounts happen) — and let
            # reverify_at_risk() settle them on reconnect
            lm.local_locks = old_lm.local_locks
            lm.held = old_lm.held
            lm.at_risk = old_lm.at_risk | set(old_lm.held)
            lm.pending_release = set(old_lm.pending_release)
        self.leases[prefix] = lm
        return m

    def _mount_for(self, path: str) -> Mount:
        for prefix in sorted(self.mounts, key=len, reverse=True):
            if path.startswith(prefix):
                return self.mounts[prefix]
        raise FileNotFoundError(f"{path}: not under any XUFS mount")

    # ---- cache fill ------------------------------------------------------
    def _read_sources(self, m: Mount, path: str,
                      nbytes: Optional[int] = None) -> List[ReadSource]:
        """Candidate servers for a cache fill, cheapest estimated
        completion first, home always last-resort.  ``nbytes`` prices
        the route with the object size when known."""
        if m.replicas is not None:
            return m.replicas.route(self.name, path, nbytes=nbytes)
        return [(m.server_name, m.store, m.token)]

    def _fetch(self, m: Mount, path: str) -> CacheEntry:
        """Whole-object striped fetch into cache space.

        With a replica fabric mounted, sources are tried nearest-first;
        a partitioned replica falls through to the next candidate (home is
        always the terminal authority).
        """
        last_exc: Optional[Exception] = None
        prev = self.cache.lookup(path)   # attr-only entries carry the size
        hint = prev.stat.size if prev is not None else None
        for server_name, store, token in self._read_sources(m, path,
                                                            nbytes=hint):
            try:
                data, st = store.get(token, path)
                self.transfer.send(server_name, self.name, data)
            except DisconnectedError as e:
                last_exc = e
                continue
            except FileNotFoundError:
                if server_name == m.server_name:
                    raise       # authoritative miss
                continue        # replica catalog raced a delete; try next
            self.cache.misses += 1
            self.cache.record_fill(server_name)
            if m.replicas is not None:
                # the serving replica's LRU clock ticks (wire-free) —
                # feeds capacity-eviction ranking
                m.replicas.note_read(server_name, path)
                # read repair: push the bytes we just pulled to any
                # replica this read observed stale — overlapped, so the
                # read's own latency is untouched.  On a capacity-bounded
                # set this doubles as demand placement: the hot path is
                # (re-)placed at replicas that never held it.
                m.replicas.read_repair(self.name, path, data, st.version,
                                       vts=store.vts_of(path) or None)
            return self.cache.store_data(path, data, st, state=VALID)
        if last_exc is not None:
            raise last_exc
        raise FileNotFoundError(path)

    def _ensure_cached(self, path: str, create_ok: bool = False) -> bytes:
        m = self._mount_for(path)
        entry = self.cache.lookup(path)
        if entry is not None and entry.state in (VALID, DIRTY):
            self.cache.hits += 1
            return self.cache.read_data(path)
        try:
            entry = self._fetch(m, path)
            return self.cache.read_data(path)
        except FileNotFoundError:
            if create_ok:
                return b""
            raise
        except DisconnectedError:
            # disconnected operation: serve stale cache if we have bytes
            if entry is not None and os.path.exists(
                    self.cache.data_path(path)):
                self.cache.hits += 1
                return self.cache.read_data(path)
            raise

    # ---- file API ----------------------------------------------------------
    def open(self, path: str, mode: str = "r") -> XufsFile:
        return XufsFile(self, path, mode)

    def _close_write(self, path: str, data: bytes) -> None:
        m = self._mount_for(path)
        st = ObjectStat(path=path, size=len(data), version=-2,
                        mtime=self.network.clock)
        prev = self.cache.lookup(path)
        if prev is not None:
            st.version = prev.stat.version
        self.cache.store_data(path, data, st, state=DIRTY)
        if not m.is_localized(path):
            self.oplog.append("store", path, data)

    def unlink(self, path: str) -> None:
        m = self._mount_for(path)
        self.cache.evict(path)
        if not m.is_localized(path):
            self.oplog.append("delete", path)

    def stat(self, path: str) -> Optional[ObjectStat]:
        """Metadata read: cached attrs first, then the nearest fresh
        replica, with home as the authoritative fallback."""
        entry = self.cache.lookup(path)
        if entry is not None and entry.state != INVALID:
            return entry.stat     # served from the hidden attr file
        m = self._mount_for(path)
        last_exc: Optional[DisconnectedError] = None
        # a stat is a 0-byte RPC: price the route with nbytes=0 so NIC
        # backlog (which cannot delay it) does not steer it off the
        # nearest replica — same rule route_meta applies to listings
        for server_name, store, token in self._read_sources(m, path,
                                                            nbytes=0):
            try:
                self.network.rpc(self.name, server_name, "stat")
            except DisconnectedError as e:
                last_exc = e
                continue
            st = store.stat(token, path)
            if st is None and server_name != m.server_name:
                continue          # replica raced a delete; try the next
            if st is not None:
                self.cache.write_entry(CacheEntry(path=path, state=EMPTY,
                                                  stat=st))
            return st             # home's answer is authoritative (even None)
        assert last_exc is not None   # home is always a candidate
        raise last_exc

    def _meta_sources(self, m: Mount, prefix: str) -> List[ReadSource]:
        """Candidate servers for a listing: replicas the catalog can prove
        complete+fresh for the prefix, nearest first, home last."""
        if m.replicas is not None:
            return m.replicas.route_meta(self.name, prefix)
        return [(m.server_name, m.store, m.token)]

    def opendir(self, path: str) -> List[ObjectStat]:
        """Download the directory listing into cache space (paper §3.1).

        Routed like data reads: the nearest replica whose holdings
        provably cover the prefix serves the (cheap, low-latency) listing;
        a partitioned source falls through to the next, ending at home.
        """
        m = self._mount_for(path)
        last_exc: Optional[DisconnectedError] = None
        for server_name, store, token in self._meta_sources(m, path):
            if self.network.is_partitioned(self.name, server_name):
                last_exc = DisconnectedError(
                    f"{self.name} <-> {server_name} partitioned")
                continue
            stats = store.listdir(token, path)
            meta_bytes = sum(64 + len(s.path) for s in stats)
            self.network.rpc(self.name, server_name, "opendir", meta_bytes)
            self.cache.populate_listing(stats)
            return stats
        assert last_exc is not None   # home is always a candidate
        raise last_exc

    def listdir_cached(self, path: str) -> List[CacheEntry]:
        return self.cache.entries(path)

    def chdir(self, path: str) -> int:
        """cd into a mounted dir: triggers the parallel small-file prefetch."""
        self.cwd = path
        from repro.core.prefetch import Prefetcher
        stats = self.opendir(path)
        pf = Prefetcher(self)
        return pf.prefetch_small(path, stats)

    # ---- write-behind sync ---------------------------------------------------
    def _apply_record(self, rec: OpRecord, data: Optional[bytes]) -> bool:
        """Apply one queued op across the write group (W-of-N ack policy).

        Returns True when the authoritative home acknowledged (the record
        may retire to ``done``) and False when a quorum acked around a
        partitioned home (the record parks at ``quorum`` until
        ``reconcile()``).  Raises :class:`QuorumNotReachedError` when
        fewer than W endpoints confirmed — the drain stops with the
        partial acks persisted.
        """
        m = self._mount_for(rec.path)
        if rec.op == "store":
            assert data is not None
            return self._apply_store(m, rec, data)
        if rec.op == "delete":
            return self._apply_delete(m, rec)
        return True

    def _apply_store(self, m: Mount, rec: OpRecord, data: bytes) -> bool:
        """One store across home + replicas, resuming from persisted acks.

        Home is always attempted first (authoritative, and it assigns the
        version); every surviving endpoint's ack is persisted in the oplog
        *before* the next endpoint is tried, so a flusher crash after W-1
        acks resumes with those acks in hand.  When home is unreachable
        the flusher takes the per-path write lease (when configured),
        pins a client-assigned version, stamps the record with a vector
        timestamp, and pushes directly to replicas nearest-first until W
        acks are in.  Reconciling a pinned record back at home is
        vts-aware: a causally-newer branch lands on top, a superseded one
        retires quietly, and concurrent branches resolve by deterministic
        last-writer-wins with the loser preserved in a
        :class:`~repro.core.tasks.ConflictRecord` — never a silent
        clobber.
        """
        reps = m.replicas
        home = m.server_name
        acked = set(rec.acked)
        home_acked = home in acked
        version = rec.version
        t0 = self.network.clock
        lease_owner = f"write:{self.owner}"
        if not home_acked:
            try:
                self.transfer.send(self.name, home, data)
                if version is None:
                    st = m.store.put(m.token, rec.path, data)
                    # stamp the connected write's causal history: it
                    # builds on whatever home held when it applied, so a
                    # parked quorum branch that never saw it reconciles
                    # as a detected conflict, not a blind overwrite
                    vts = vts_merge(m.store.vts_of(rec.path),
                                    self._vts_frontier.get(rec.path))
                    vts[self.owner] = vts.get(self.owner, 0) + 1
                    m.store.set_vts(rec.path, vts)
                    rec.vts = dict(vts)
                    self._vts_frontier[rec.path] = dict(vts)
                else:                # replay/reconcile: idempotent re-apply
                    st, outcome = self._reconcile_pinned(m, rec, data,
                                                         version)
                    if outcome in ("superseded", "conflict-lost"):
                        # home's causal history already covers (or beat)
                        # this branch: retire the record WITHOUT fanning
                        # its stale bytes out; replicas converge from
                        # home via resync/repair
                        self.oplog.mark_acked(rec, home,
                                              version=st.version, home=True)
                        if reps is not None \
                                and reps.write_lease is not None:
                            reps.release_write_lease(self.name, rec.path,
                                                     lease_owner)
                        cur = self.cache.lookup(rec.path)
                        if cur is not None:
                            self.cache.write_entry(CacheEntry(
                                path=rec.path, state=INVALID, stat=st))
                        self._note_ack(rec.seq,
                                       self.network.clock - t0)
                        return True
                version = st.version
                self.oplog.mark_acked(rec, home, version=version, home=True)
                acked.add(home)
                home_acked = True
                cur = self.cache.lookup(rec.path)
                if cur is not None and cur.state == DIRTY:
                    self.cache.write_entry(CacheEntry(
                        path=rec.path, state=VALID, stat=st))
                if reps is not None and reps.write_lease is not None:
                    # the lease's job — no competing client-assigned
                    # versions — ends once home holds the write
                    reps.release_write_lease(self.name, rec.path,
                                             lease_owner)
            except DisconnectedError:
                pass     # home partitioned: try to assemble a replica quorum
        if reps is None:
            if not home_acked:
                raise DisconnectedError(f"{home} unreachable (no replicas)")
            self._note_ack(rec.seq, self.network.clock - t0)
            return True
        w = reps.resolve_w()
        if w <= 1 and not home_acked:
            # W=1 is the legacy policy: the home apply IS the ack; replica
            # fan-out stays best-effort, so a home outage stalls the drain.
            raise DisconnectedError(f"{home} unreachable (W=1 acks at home)")
        if version is None:
            # first quorum attempt around a dead home: serialize via the
            # write lease when one is configured, then pin version + vts
            if reps.write_lease is not None:
                if reps.acquire_write_lease(self.name, rec.path,
                                            lease_owner) is False:
                    raise WriteLeaseContended(
                        f"{rec.path}: write lease held by another writer")
            version = reps.next_version(rec.path)
            vts = vts_merge(reps.vts_frontier(self.name, rec.path),
                            self._vts_frontier.get(rec.path))
            vts[self.owner] = vts.get(self.owner, 0) + 1
            rec.vts = vts           # persisted with the first replica ack
            self._vts_frontier[rec.path] = dict(vts)
        quorum_clock: Optional[float] = None
        if len(acked) >= w:
            quorum_clock = self.network.clock
        # home forwards when it has the bytes (third-party transfer);
        # otherwise the client pushes directly.  Every apply is launched
        # as overlapped channel reservations FIRST; acks are then
        # collected in completion order, and the clock advances only to
        # the W-th — acks beyond the quorum settle in the background,
        # which is exactly why a W<N drain beats W=all on elapsed time.
        # fan-out launches cheapest-estimated-completion first (queue
        # depth + NIC backlog included), so the W-th ack lands as early
        # as the current congestion state allows
        src = reps.home_name if home_acked else self.name
        # replicas receive the authoritative frontier once home acked
        # (reconcile may have merged branches there); otherwise the
        # record's own stamp rides the fan-out
        fan_vts = (m.store.vts_of(rec.path) or None) if home_acked \
            else rec.vts
        launched = []
        for name in reps.replicas_by_cost(src, len(data)):
            if name in acked:
                continue
            p = reps.begin_apply(name, rec.path, data, version, src=src,
                                 vts=fan_vts)
            if p is not None:
                launched.append(p)
        # acks pop in completion order (heap, launch order on ties) —
        # the event-engine analogue of sorting the pending list
        ack_heap = [(p.ack.completion, i, p)
                    for i, p in enumerate(launched)]
        heapq.heapify(ack_heap)
        pending = [p for _c, _i, p in
                   (heapq.heappop(ack_heap) for _ in range(len(ack_heap)))]
        for p in pending:
            reps.complete_apply(p)
            self.oplog.mark_acked(rec, p.name, version=version)
            acked.add(p.name)
            if len(acked) >= w and quorum_clock is None:
                self.network.wait(p.ack)
                quorum_clock = self.network.clock
        if len(acked) < w:
            # the flusher waited out every launched apply before giving up
            self.network.wait_all([p.ack for p in pending])
            raise QuorumNotReachedError(
                f"{rec.path}: {len(acked)}/{w} acks "
                f"(N={reps.n_endpoints})")
        self._note_ack(rec.seq, quorum_clock - t0)
        if not home_acked:
            reps.catalog.note_quorum(rec.path, version)
            return False
        return True

    def _reconcile_pinned(self, m: Mount, rec: OpRecord, data: bytes,
                          version: int) -> Tuple[ObjectStat, str]:
        """Land a version-pinned record back at home (replay/reconcile),
        vts-aware.  Returns ``(home stat, outcome)`` with outcome one of
        ``"apply"`` / ``"superseded"`` / ``"conflict-won"`` /
        ``"conflict-lost"``.

        Legacy records (no stamp — pre-vts WAL lines) keep the
        historical blind put-on-top.  Stamped records compare causal
        histories first: a branch home already includes retires quietly;
        a branch that includes home's state lands on top; two branches
        that know nothing of each other are a true conflict — resolved
        by the deterministic last-writer-wins order (``vts_lww_key``)
        and preserved, both sides, in a :class:`ConflictRecord`.
        """
        if rec.vts is None:
            st = m.store.apply_versioned(m.token, rec.path, data, version)
            if st.version > version:
                # Home is past our pinned version without having seen
                # these bytes (the catalog under-counted when the quorum
                # was assembled): the quorum ack promised durability of
                # THIS write, so it lands on top.
                st = m.store.put(m.token, rec.path, data,
                                 version=st.version + 1)
            return st, "apply"
        home_vts = m.store.vts_of(rec.path)
        rvts = dict(rec.vts)
        if vts_dominates(home_vts, rvts):
            # our write is already in home's causal past: a duplicate
            # reconcile, or a later writer built on our branch (it
            # merged our frontier from a common replica) and landed
            # first — either way, re-applying would roll home back
            st = m.store.stat(m.token, rec.path)
            if st is None:        # deleted at home after superseding us
                st = ObjectStat(path=rec.path, size=0, version=version,
                                mtime=self.network.clock)
            return st, "superseded"
        if vts_dominates(rvts, home_vts):
            st = m.store.apply_versioned(m.token, rec.path, data, version)
            if st.version > version:
                st = m.store.put(m.token, rec.path, data,
                                 version=st.version + 1)
            m.store.set_vts(rec.path, rvts)
            return st, "apply"
        # concurrent branches: neither knows about the other.  Land the
        # deterministic LWW winner's bytes at a version past BOTH
        # branches — even when home's current bytes win, the version
        # bump makes home the freshness floor again, so replicas still
        # holding the losing branch get repaired instead of serving it.
        theirs_data, cur = m.store.get(m.token, rec.path)
        merged = vts_merge(rvts, home_vts)
        ours_win = vts_lww_key(rvts) > vts_lww_key(home_vts)
        st = m.store.put(m.token, rec.path,
                         data if ours_win else theirs_data,
                         version=max(cur.version, version) + 1)
        m.store.set_vts(rec.path, merged)
        self._note_conflict(ConflictRecord(
            path=rec.path, seq=rec.seq, owner=self.owner,
            ours_vts=rvts, theirs_vts=dict(home_vts),
            winner="ours" if ours_win else "theirs",
            ours_data=data, theirs_data=theirs_data,
            detected_at=self.network.clock,
            _apply=self._conflict_override_fn(m, rec.path, merged)))
        return st, ("conflict-won" if ours_win else "conflict-lost")

    def _note_conflict(self, record: ConflictRecord) -> None:
        self.conflicts.append(record)
        if self._conflict_sink is not None:
            self._conflict_sink(record)

    def _conflict_override_fn(self, m: Mount, path: str,
                              merged: Dict[str, int]
                              ) -> Callable[[bytes], None]:
        """Bound apply for ``ConflictRecord.resolve()``: re-lands the
        operator's chosen branch on top at home (a real wire write)."""
        def apply_override(data: bytes) -> None:
            self.transfer.send(self.name, m.server_name, data)
            st = m.store.stat_unchecked(path)
            m.store.put(m.token, path, data,
                        version=(st.version + 1) if st is not None else 1)
            m.store.set_vts(path, dict(merged))
        return apply_override

    def _apply_delete(self, m: Mount, rec: OpRecord) -> bool:
        """Deletes stay home-first: the authoritative tombstone must land
        at home before replicas drop their copies (fan-out best-effort)."""
        self.network.rpc(self.name, m.server_name, "delete")
        try:
            m.store.delete(m.token, rec.path)
        except FileNotFoundError:
            pass
        self.oplog.retire_superseded(rec.path, rec.seq)
        if m.replicas is not None:
            m.replicas.propagate_delete(rec.path)
            m.replicas.catalog.forget_quorum(rec.path)
        return True

    def pump(self, max_ops: Optional[int] = None) -> int:
        """Drain the meta-op queue (the background flusher tick).

        Returns the number of ops that became client-complete: home-acked
        and retired, or quorum-acked around a partitioned home.
        """
        return self.oplog.flush(self._apply_record, max_ops=max_ops)

    def reconcile(self) -> int:
        """Land the home apply for quorum-parked ops once home heals."""
        return self.oplog.reconcile(self._apply_record)

    def replay(self) -> int:
        """Post-crash sync: re-drain pending ops, then repair replicas.

        Per-endpoint acks are persisted as they arrive, so a flusher
        crash mid-quorum resumes from the recorded ack set instead of
        re-earning it; ``reconcile()`` then retires quorum-parked ops
        whose home heal landed, and the trailing ``resync`` converges
        replicas that were partitioned during fan-out or missed
        notifications.
        """
        n = self.oplog.replay(self._apply_record)
        self.reconcile()
        # paths still awaiting home reconciliation are off-limits to
        # anti-entropy: home's copy is older than the acked quorum write
        parked = {r.path for r in self.oplog.unreconciled()}
        seen = set()      # mounts may share one ReplicaSet: resync it once
        for m in self.mounts.values():
            if m.replicas is not None and id(m.replicas) not in seen:
                seen.add(id(m.replicas))
                m.replicas.resync(skip=parked)
        return n

    def sync(self) -> int:
        """Blocking drain (the paper's post-crash sync tool)."""
        total = 0
        while True:
            n = self.pump()
            if not self.oplog.pending():
                return total + n
            if n == 0:
                return total
            total += n

    # ---- consistency / recovery ----------------------------------------------
    def pump_callbacks(self) -> int:
        return sum(nm.pump() for nm in self.notifiers.values())

    def reconnect(self) -> int:
        """After a server crash/partition heals: re-learn and re-register.

        Guarantees on return: every mount's replica fabric is reattached
        (catalog feed re-subscribed, home version vector re-learned when
        reachable), quorum-parked writes were offered to home for
        reconciliation, and the callback channel is re-registered with
        every cached entry revalidated by version.  A home that is
        *still* down does not fail the call — the client stays in
        disconnected operation against the surviving quorum and keeps
        flushing through ``pump()``.
        """
        stale = 0
        parked = {r.path for r in self.oplog.unreconciled()}
        seen = set()
        for prefix, nm in self.notifiers.items():
            m = self.mounts[prefix]
            if m.replicas is not None and id(m.replicas) not in seen:
                seen.add(id(m.replicas))
                m.replicas.reattach(token=m.token, via=self.name,
                                    skip=parked)
            try:
                stale += nm.reconnect(m.token)
            except DisconnectedError:
                continue             # home still down: stay disconnected
            lm = self.leases.get(prefix)
            if lm is not None and lm.at_risk:
                # leases a partition-interrupted renewal (or a token
                # rotation) left unconfirmed: re-verify with the server
                # now that the channel is back, dropping any it expired
                lm.reverify_at_risk()
        self.reconcile()
        return stale

    # ---- locks -------------------------------------------------------------
    def lock(self, path: str) -> bool:
        m = self._mount_for(path)
        return self.leases[m.prefix].acquire(path,
                                             localized=m.is_localized(path))

    def unlock(self, path: str) -> None:
        m = self._mount_for(path)
        self.leases[m.prefix].release(path)
