"""XufsClient: the interposition seam (the paper's libxufs.so equivalent).

Applications (the trainer, the serving engine, the data pipeline) perform
all file access through this client.  Semantics per the paper:

  * ``opendir`` materializes the remote listing into cache space (hidden
    attribute files) and redirects directory ops locally;
  * first ``open`` of a file fetches the WHOLE object (striped);
  * mutating ops update the cache copy, append to the persisted meta-op
    queue, and return — nothing blocks on the WAN;
  * ``write`` accumulates in a shadow buffer; ``close`` enqueues one
    aggregated store op (**last-close-wins**);
  * callback invalidations mark entries stale; next access re-fetches;
  * *localized directories*: new data never ships back to home;
  * disconnected operation: reads serve from cache, writes queue.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.cache import (
    CacheSpace, CacheEntry, EMPTY, VALID, DIRTY, INVALID,
)
from repro.core.callbacks import NotificationManager
from repro.core.lease import LeaseManager
from repro.core.oplog import MetaOpQueue, OpRecord
from repro.core.replication import ReadSource, ReplicaSet
from repro.core.store import HomeStore, ObjectStat
from repro.core.striping import StripedTransfer
from repro.core.transport import DisconnectedError, Network


@dataclass
class Mount:
    prefix: str                      # namespace prefix, e.g. "home/"
    server_name: str
    store: HomeStore
    token: str
    localized: List[str] = field(default_factory=list)
    replicas: Optional[ReplicaSet] = None

    def is_localized(self, path: str) -> bool:
        return any(path.startswith(ld) for ld in self.localized)


class XufsFile:
    """An open file handle over the cache copy + shadow write buffer."""

    def __init__(self, client: "XufsClient", path: str, mode: str):
        assert mode in ("r", "w", "a", "rw")
        self.client = client
        self.path = path
        self.mode = mode
        self.closed = False
        if "r" in mode or mode == "a":
            base = client._ensure_cached(path, create_ok="w" in mode or
                                         mode == "a")
        else:
            base = b""
        self._buf = bytearray(base if mode != "w" else b"")
        self._dirty = mode in ("w", "a")
        self._pos = len(self._buf) if mode == "a" else 0

    # ---- POSIX-ish surface -------------------------------------------------
    def read(self, n: int = -1) -> bytes:
        end = len(self._buf) if n < 0 else min(self._pos + n, len(self._buf))
        out = bytes(self._buf[self._pos:end])
        self._pos = end
        return out

    def write(self, data: bytes) -> int:
        end = self._pos + len(data)
        if end > len(self._buf):
            self._buf.extend(b"\x00" * (end - len(self._buf)))
        self._buf[self._pos:end] = data
        self._pos = end
        self._dirty = True
        return len(data)

    def seek(self, pos: int) -> None:
        self._pos = pos

    def close(self) -> None:
        """Update the cache copy; enqueue ONE aggregated store op."""
        if self.closed:
            return
        self.closed = True
        if self._dirty:
            self.client._close_write(self.path, bytes(self._buf))

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class XufsClient:
    def __init__(self, name: str, network: Network, cache_root: str,
                 oplog_root: str, owner: str = "user"):
        self.name = name
        self.network = network
        self.cache = CacheSpace(cache_root)
        self.oplog = MetaOpQueue(oplog_root)
        self.transfer = StripedTransfer(network)
        self.mounts: Dict[str, Mount] = {}
        self.notifiers: Dict[str, NotificationManager] = {}
        self.leases: Dict[str, LeaseManager] = {}
        self.owner = owner
        self.cwd = ""

    # ---- mounts -----------------------------------------------------------
    def mount(self, prefix: str, server_name: str, store: HomeStore,
              token: str, localized: Optional[List[str]] = None,
              replicas: Optional[ReplicaSet] = None) -> Mount:
        m = Mount(prefix=prefix, server_name=server_name, store=store,
                  token=token, localized=localized or [],
                  replicas=replicas)
        self.mounts[prefix] = m
        nm = NotificationManager(self.network, self.name, server_name,
                                 store, self.cache, prefix=prefix)
        nm.register(token)
        self.notifiers[prefix] = nm
        self.leases[prefix] = LeaseManager(
            self.network, self.name, server_name, store, owner=self.owner,
            token=token)
        return m

    def _mount_for(self, path: str) -> Mount:
        for prefix in sorted(self.mounts, key=len, reverse=True):
            if path.startswith(prefix):
                return self.mounts[prefix]
        raise FileNotFoundError(f"{path}: not under any XUFS mount")

    # ---- cache fill ------------------------------------------------------
    def _read_sources(self, m: Mount, path: str) -> List[ReadSource]:
        """Candidate servers for a cache fill, nearest first, home last."""
        if m.replicas is not None:
            return m.replicas.route(self.name, path)
        return [(m.server_name, m.store, m.token)]

    def _fetch(self, m: Mount, path: str) -> CacheEntry:
        """Whole-object striped fetch into cache space.

        With a replica fabric mounted, sources are tried nearest-first;
        a partitioned replica falls through to the next candidate (home is
        always the terminal authority).
        """
        last_exc: Optional[Exception] = None
        for server_name, store, token in self._read_sources(m, path):
            try:
                data, st = store.get(token, path)
                self.transfer.send(server_name, self.name, data)
            except DisconnectedError as e:
                last_exc = e
                continue
            except FileNotFoundError:
                if server_name == m.server_name:
                    raise       # authoritative miss
                continue        # replica catalog raced a delete; try next
            self.cache.misses += 1
            self.cache.record_fill(server_name)
            return self.cache.store_data(path, data, st, state=VALID)
        if last_exc is not None:
            raise last_exc
        raise FileNotFoundError(path)

    def _ensure_cached(self, path: str, create_ok: bool = False) -> bytes:
        m = self._mount_for(path)
        entry = self.cache.lookup(path)
        if entry is not None and entry.state in (VALID, DIRTY):
            self.cache.hits += 1
            return self.cache.read_data(path)
        try:
            entry = self._fetch(m, path)
            return self.cache.read_data(path)
        except FileNotFoundError:
            if create_ok:
                return b""
            raise
        except DisconnectedError:
            # disconnected operation: serve stale cache if we have bytes
            if entry is not None and os.path.exists(
                    self.cache.data_path(path)):
                self.cache.hits += 1
                return self.cache.read_data(path)
            raise

    # ---- file API ----------------------------------------------------------
    def open(self, path: str, mode: str = "r") -> XufsFile:
        return XufsFile(self, path, mode)

    def _close_write(self, path: str, data: bytes) -> None:
        m = self._mount_for(path)
        st = ObjectStat(path=path, size=len(data), version=-2,
                        mtime=self.network.clock)
        prev = self.cache.lookup(path)
        if prev is not None:
            st.version = prev.stat.version
        self.cache.store_data(path, data, st, state=DIRTY)
        if not m.is_localized(path):
            self.oplog.append("store", path, data)

    def unlink(self, path: str) -> None:
        m = self._mount_for(path)
        entry = self.cache.lookup(path)
        if entry is not None:
            dp = self.cache.data_path(path)
            if os.path.exists(dp):
                os.remove(dp)
            ap = self.cache.attr_path(path)
            if os.path.exists(ap):
                os.remove(ap)
        if not m.is_localized(path):
            self.oplog.append("delete", path)

    def stat(self, path: str) -> Optional[ObjectStat]:
        entry = self.cache.lookup(path)
        if entry is not None and entry.state != INVALID:
            return entry.stat     # served from the hidden attr file
        m = self._mount_for(path)
        st = m.store.stat(m.token, path)
        self.network.rpc(self.name, m.server_name, "stat")
        if st is not None:
            self.cache.write_entry(CacheEntry(path=path, state=EMPTY,
                                              stat=st))
        return st

    def opendir(self, path: str) -> List[ObjectStat]:
        """Download the directory listing into cache space (paper §3.1)."""
        m = self._mount_for(path)
        stats = m.store.listdir(m.token, path)
        meta_bytes = sum(64 + len(s.path) for s in stats)
        self.network.rpc(self.name, m.server_name, "opendir", meta_bytes)
        self.cache.populate_listing(stats)
        return stats

    def listdir_cached(self, path: str) -> List[CacheEntry]:
        return self.cache.entries(path)

    def chdir(self, path: str) -> int:
        """cd into a mounted dir: triggers the parallel small-file prefetch."""
        self.cwd = path
        from repro.core.prefetch import Prefetcher
        stats = self.opendir(path)
        pf = Prefetcher(self)
        return pf.prefetch_small(path, stats)

    # ---- write-behind sync ---------------------------------------------------
    def _apply_record(self, rec: OpRecord, data: Optional[bytes]) -> None:
        """Apply one queued op: home first (authoritative), then fan out.

        The replica fan-out runs after the home apply and absorbs WAN
        faults internally, so a lagging or partitioned replica never
        blocks the flusher; a crash between the home apply and the fan-out
        leaves the record pending, and ``replay()`` re-converges.
        """
        m = self._mount_for(rec.path)
        if rec.op == "store":
            assert data is not None
            self.transfer.send(self.name, m.server_name, data)
            st = m.store.put(m.token, rec.path, data)
            cur = self.cache.lookup(rec.path)
            if cur is not None and cur.state == DIRTY:
                self.cache.write_entry(CacheEntry(
                    path=rec.path, state=VALID, stat=st))
            if m.replicas is not None:
                m.replicas.propagate(rec.path, data, st)
        elif rec.op == "delete":
            self.network.rpc(self.name, m.server_name, "delete")
            try:
                m.store.delete(m.token, rec.path)
            except FileNotFoundError:
                pass
            if m.replicas is not None:
                m.replicas.propagate_delete(rec.path)

    def pump(self, max_ops: Optional[int] = None) -> int:
        """Drain the meta-op queue to home (the background flusher tick)."""
        return self.oplog.flush(self._apply_record, max_ops=max_ops)

    def replay(self) -> int:
        """Post-crash sync: re-drain pending ops, then repair replicas.

        Records are marked done only after both the home apply and the
        fan-out complete, so a flusher crash in between replays the whole
        record; the trailing ``resync`` converges replicas that were
        partitioned during fan-out or missed notifications.
        """
        n = self.oplog.replay(self._apply_record)
        seen = set()      # mounts may share one ReplicaSet: resync it once
        for m in self.mounts.values():
            if m.replicas is not None and id(m.replicas) not in seen:
                seen.add(id(m.replicas))
                m.replicas.resync()
        return n

    def sync(self) -> int:
        """Blocking drain (the paper's post-crash sync tool)."""
        total = 0
        while True:
            n = self.pump()
            if not self.oplog.pending():
                return total + n
            if n == 0:
                return total
            total += n

    # ---- consistency / recovery ----------------------------------------------
    def pump_callbacks(self) -> int:
        return sum(nm.pump() for nm in self.notifiers.values())

    def reconnect(self) -> int:
        """After a server crash/partition heals: re-register + revalidate."""
        stale = 0
        for prefix, nm in self.notifiers.items():
            m = self.mounts[prefix]
            stale += nm.reconnect(m.token)
        return stale

    # ---- locks -------------------------------------------------------------
    def lock(self, path: str) -> bool:
        m = self._mount_for(path)
        return self.leases[m.prefix].acquire(path,
                                             localized=m.is_localized(path))

    def unlock(self, path: str) -> None:
        m = self._mount_for(path)
        self.leases[m.prefix].release(path)
