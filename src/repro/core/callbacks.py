"""Notification callback manager (paper §3.1, AFS-2 style consistency).

The client registers a callback channel with the home server; any home-side
change pushes an invalidation.  Cached copies are assumed fresh unless
notified — no per-open version checks (unlike NFS/Jade).  If the channel
breaks (server crash / partition), the client enters disconnected mode and
on reconnect re-registers and revalidates every cached entry by version.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Tuple

from repro.core.cache import CacheSpace, VALID, EMPTY
from repro.core.store import HomeStore, ObjectStat
from repro.core.transport import DisconnectedError, Network


@dataclass
class NotificationManager:
    network: Network
    client_name: str
    server_name: str
    store: HomeStore
    cache: CacheSpace
    prefix: str = ""
    connected: bool = False
    pending: List[Tuple[str, ObjectStat]] = field(default_factory=list)
    breaks: int = 0
    _cb: Optional[Callable] = None

    # ---- channel lifecycle ------------------------------------------------
    def register(self, token: str) -> None:
        """Open the callback channel (one RPC) and subscribe server-side."""
        self.network.rpc(self.client_name, self.server_name,
                         "register_callbacks")
        self.store.check(token)

        def _cb(path: str, st: ObjectStat) -> None:
            # server pushes over the (modeled) channel; queue client-side
            if self.prefix and not path.startswith(self.prefix):
                return
            self.pending.append((path, st))

        self._cb = _cb
        self.store.subscribe(_cb)
        self.connected = True

    def teardown(self) -> None:
        if self._cb is not None:
            self.store.unsubscribe(self._cb)
            self._cb = None
        self.connected = False

    # ---- pump: deliver queued notifications --------------------------------
    def pump(self) -> int:
        """Apply queued invalidations.  Detects a broken channel."""
        if not self.connected:
            return 0
        try:
            # channel liveness probe rides the persistent TCP connection
            self.network.rpc(self.client_name, self.server_name,
                             "callback_keepalive")
        except DisconnectedError:
            self.connected = False
            self.breaks += 1
            return 0
        n = 0
        while self.pending:
            path, st = self.pending.pop(0)
            if st.version < 0:
                self.cache.invalidate(path)     # deletion
            else:
                self.cache.invalidate(path, st)
            n += 1
        return n

    # ---- recovery ------------------------------------------------------------
    def reconnect(self, token: str) -> int:
        """Re-register after a break and revalidate all cached entries.

        The per-entry ``revalidate_stat`` probes are pipelined over the
        channel pool (they are independent round-trips), so revalidating
        a big cache costs ~ceil(entries / channels) RTTs instead of one
        RTT per entry.  Returns the number of entries found stale (and
        invalidated).
        """
        self.pending.clear()
        if self._cb is not None:
            self.store.unsubscribe(self._cb)
        self.register(token)
        entries = self.cache.entries(self.prefix)
        probes = [self.network.transfer(self.client_name, self.server_name,
                                        "revalidate_stat")
                  for _ in entries]
        self.network.wait_all(probes)
        stale = 0
        for entry in entries:
            st = self.store.stat(token, entry.path)
            if st is None:
                self.cache.invalidate(entry.path)
                stale += 1
            elif st.version > entry.stat.version:
                self.cache.invalidate(entry.path, st)
                stale += 1
        return stale
