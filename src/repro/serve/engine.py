"""Batched serving engine: slot-based continuous batching over the KV cache.

``ServeEngine`` holds a fixed pool of batch slots.  Requests are admitted
into free slots, prefilled (one request at a time — prompt lengths vary),
then all active slots decode together with one jitted ``decode_step`` per
token.  Weights arrive through the XUFS fabric (striped restore +
small-tensor prefetch) via serve/loader.py.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.models import init_cache, prefill, decode_step


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    temperature: float = 0.0
    output: List[int] = field(default_factory=list)
    done: bool = False


@dataclass
class SlotState:
    rid: int = -1
    active: bool = False
    remaining: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params: Any, *, slots: int = 4,
                 max_len: int = 512, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache = init_cache(cfg, slots, max_len)
        self.slot_states = [SlotState() for _ in range(slots)]
        self.requests: Dict[int, Request] = {}
        self.queue: List[int] = []
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c: decode_step(self.cfg, p, t, c))
        # per-slot last emitted token (feeds the next decode step)
        self.last_tokens = np.zeros((slots, 1), np.int32)
        self.tokens_generated = 0

    # ---- admission ----------------------------------------------------------
    def add_request(self, req: Request) -> None:
        self.requests[req.rid] = req
        self.queue.append(req.rid)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slot_states):
            if not s.active:
                return i
        return None

    def _admit(self) -> int:
        """Prefill queued requests into free slots."""
        admitted = 0
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                break
            rid = self.queue.pop(0)
            req = self.requests[rid]
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            S = toks.shape[1]
            batch = {
                "tokens": toks,
                "positions": jnp.arange(S, dtype=jnp.int32)[None, :],
            }
            logits, cache1 = prefill(self.cfg, self.params, batch,
                                     max_len=self.max_len)
            # splice this request's prefilled cache into the shared pool
            self._splice_cache(slot, cache1)
            tok = self._sample(logits[:, -1, :], req.temperature)
            req.output.append(int(tok[0]))
            self.last_tokens[slot, 0] = int(tok[0])
            st = self.slot_states[slot]
            st.rid, st.active, st.remaining = rid, True, \
                req.max_new_tokens - 1
            admitted += 1
        return admitted

    def _splice_cache(self, slot: int, cache1: Any) -> None:
        def splice(pool, one):
            if pool.ndim == 0 or one.ndim == 0:
                return pool
            # slot batch axis is dim 1 for [L, B, ...] entries
            return jax.lax.dynamic_update_slice_in_dim(
                pool, one.astype(pool.dtype), slot, axis=1)

        new_cache = {}
        for k, vpool in self.cache.items():
            if k == "index":
                # per-slot write positions (continuous batching)
                new_cache[k] = jax.lax.dynamic_update_slice_in_dim(
                    vpool, cache1[k].astype(vpool.dtype), slot, axis=0)
                continue
            new_cache[k] = splice(vpool, cache1[k])
        self.cache = new_cache

    # ---- sampling --------------------------------------------------------------
    def _sample(self, logits: jax.Array, temperature: float) -> np.ndarray:
        if temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.key, sub = jax.random.split(self.key)
        return np.asarray(
            jax.random.categorical(sub, logits / temperature, axis=-1),
            np.int32)

    # ---- one engine tick -----------------------------------------------------
    def step(self) -> int:
        """Admit + one decode for all active slots.  Returns tokens emitted."""
        self._admit()
        if not any(s.active for s in self.slot_states):
            return 0
        toks = jnp.asarray(self.last_tokens)
        logits, self.cache = self._decode(self.params, toks, self.cache)
        emitted = 0
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        for i, st in enumerate(self.slot_states):
            if not st.active:
                continue
            req = self.requests[st.rid]
            tok = int(nxt[i])
            req.output.append(tok)
            self.last_tokens[i, 0] = tok
            st.remaining -= 1
            emitted += 1
            self.tokens_generated += 1
            if st.remaining <= 0:
                req.done = True
                st.active = False
                st.rid = -1
        return emitted

    def run_until_done(self, max_ticks: int = 1000) -> None:
        for _ in range(max_ticks):
            if not self.queue and not any(s.active for s in self.slot_states):
                return
            self.step()
