"""Pallas TPU chunked WKV6 scan (RWKV6 "Finch" time mixing).

Algorithm (flash-linear-attention style, log-space chunking): for a chunk
of C tokens with per-token per-channel decay w_t ∈ (0,1),

    L_t  = Σ_{j<=t} log w_j                     (chunk-local, L_0 = 0)
    y_t  = (r_t ⊙ e^{L_{t-1}}) S_0              (inter-chunk, matmul)
         + Σ_{s<t} [r_t·k_s ⊙ e^{L_{t-1}-L_s}] v_s   (intra, [C,C,D] masked)
         + (r_t · u ⊙ k_t) v_t                  (diagonal bonus term)
    S'   = diag(e^{L_C}) S_0 + Σ_s (k_s ⊙ e^{L_C - L_s})^T v_s

All exponentials have non-positive arguments, so the chunked form is
numerically safe.  The recurrent state S [D,D] stays in VMEM scratch across
the (sequential) chunk grid axis — the whole sequence makes zero HBM
round-trips for state.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_CHUNK = 64


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_scr, *,
                chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)          # [C, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # [1, D]
    S0 = s_scr[...]                           # [D, D]

    logw = jnp.log(jnp.maximum(w, 1e-37))
    L = jnp.cumsum(logw, axis=0)              # [C, D]  (= L_t)
    L_prev = L - logw                         # [C, D]  (= L_{t-1})

    # inter-chunk: (r ⊙ e^{L_prev}) @ S0
    r_dec = r * jnp.exp(L_prev)
    y = jax.lax.dot_general(r_dec, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk: A[t,s] = Σ_d r[t,d] k[s,d] e^{L_prev[t,d]-L[s,d]} (s<t)
    expo = L_prev[:, None, :] - L[None, :, :]            # [C, C, D]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    gated = jnp.where(tri[:, :, None], jnp.exp(expo), 0.0)
    A = jnp.einsum("td,sd,tsd->ts", r, k, gated)
    # diagonal bonus: r_t · (u ⊙ k_t)
    diag = jnp.sum(r * u * k, axis=-1)                    # [C]
    A = A + jnp.diag(diag)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # state update: S' = diag(e^{L_C}) S0 + (k ⊙ e^{L_C - L_s})^T v
    L_total = L[-1:, :]                                   # [1, D]
    k_dec = k * jnp.exp(L_total - L)
    s_scr[...] = (jnp.exp(L_total).T * S0
                  + jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))
    o_ref[0] = y.astype(o_ref.dtype)


def rwkv6_scan(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
               u: jax.Array, *, chunk: int = DEFAULT_CHUNK,
               interpret: bool = False) -> jax.Array:
    """r,k,v,w: [B,H,S,D]; u: [H,D] -> y [B,H,S,D] float32."""
    B, H, S, D = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    resh = lambda t: t.reshape(B * H, S, D)
    ur = jnp.broadcast_to(u[None], (B, H, D)).reshape(B * H, 1, D)

    def x_map(bh, ci):
        return (bh, ci, 0)

    def u_map(bh, ci):
        return (bh, 0, 0)

    kernel = functools.partial(_wkv_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[pl.BlockSpec((1, chunk, D), x_map)] * 4
        + [pl.BlockSpec((1, 1, D), u_map)],
        out_specs=pl.BlockSpec((1, chunk, D), x_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), jnp.float32),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(resh(r), resh(k), resh(v), resh(w), ur)
    return out.reshape(B, H, S, D)
