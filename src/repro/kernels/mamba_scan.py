"""Pallas TPU chunked selective-scan (Mamba-1 SSM).

Grid = (B, di/block_d, S/chunk); the SSM state h [block_d, N] lives in VMEM
scratch across the sequential chunk axis, so the recurrence never round-trips
HBM.  Within a chunk the recurrence is stepped with a fori_loop over VMEM
tiles (the update is elementwise VPU work — there is no MXU contraction to
tile, N=16 — so the win is state residency + input tile reuse).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_CHUNK = 64
DEFAULT_BLOCK_D = 256


def _mamba_kernel(A_ref, dt_ref, b_ref, c_ref, x_ref, o_ref, h_scr, *,
                  chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = A_ref[...].astype(jnp.float32)         # [bd, N]
    dt = dt_ref[0].astype(jnp.float32)         # [C, bd]
    b = b_ref[0].astype(jnp.float32)           # [C, N]
    c = c_ref[0].astype(jnp.float32)           # [C, N]
    x = x_ref[0].astype(jnp.float32)           # [C, bd]

    def step(t, carry):
        h, ys = carry
        dt_t = dt[t]                           # [bd]
        dA = jnp.exp(dt_t[:, None] * A)        # [bd, N]
        dBx = (dt_t * x[t])[:, None] * b[t][None, :]
        h = dA * h + dBx
        y = jnp.sum(h * c[t][None, :], axis=-1)          # [bd]
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y[None], t, 0)
        return h, ys

    h0 = h_scr[...]
    ys0 = jnp.zeros((chunk, dt.shape[1]), jnp.float32)
    hT, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scr[...] = hT
    o_ref[0] = ys.astype(o_ref.dtype)


def mamba_scan(A: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
               x: jax.Array, *, chunk: int = DEFAULT_CHUNK,
               block_d: int = DEFAULT_BLOCK_D,
               interpret: bool = False) -> jax.Array:
    """A: [di,N]; dt,x: [B,S,di]; b,c: [B,S,N] -> y [B,S,di] float32."""
    B, S, di = x.shape
    N = A.shape[1]
    chunk = min(chunk, S)
    block_d = min(block_d, di)
    assert S % chunk == 0 and di % block_d == 0
    nc, nd = S // chunk, di // block_d

    kernel = functools.partial(_mamba_kernel, chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=(B, nd, nc),
        in_specs=[
            pl.BlockSpec((block_d, N), lambda bi, di_, ci: (di_, 0)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, di_, ci: (bi, ci, di_)),
            pl.BlockSpec((1, chunk, N), lambda bi, di_, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, N), lambda bi, di_, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, di_, ci: (bi, ci, di_)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda bi, di_, ci: (bi, ci, di_)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(A, dt, b, c, x)
    return out
