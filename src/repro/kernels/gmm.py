"""Pallas TPU grouped matmul (megablox-lite) for MoE expert FFNs.

lhs [M, K] holds tokens sorted by expert; rhs [G, K, N] stacks expert
weights.  The ops.py wrapper pads each group's row count to a multiple of
``block_m``, so every m-tile maps to exactly ONE group — the group id per
tile is passed as a scalar-prefetch operand and selects the rhs block via
its index_map.  Accumulation over K tiles happens in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_K = 512
DEFAULT_BLOCK_N = 512


def _gmm_kernel(gid_ref, lhs_ref, rhs_ref, o_ref, acc_scr, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jax.lax.dot_general(
        lhs_ref[...].astype(jnp.float32), rhs_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def gmm(lhs: jax.Array, rhs: jax.Array, tile_group_ids: jax.Array, *,
        block_m: int = DEFAULT_BLOCK_M, block_k: int = DEFAULT_BLOCK_K,
        block_n: int = DEFAULT_BLOCK_N, interpret: bool = False) -> jax.Array:
    """lhs: [M,K]; rhs: [G,K,N]; tile_group_ids: [M/block_m] -> [M,N].

    Requires group boundaries aligned to block_m (ops.py pads to this).
    """
    M, K = lhs.shape
    G, _, N = rhs.shape
    block_m = min(block_m, M)
    block_k = min(block_k, K)
    block_n = min(block_n, N)
    assert M % block_m == 0 and K % block_k == 0 and N % block_n == 0
    nm, nk, nn = M // block_m, K // block_k, N // block_n
    assert tile_group_ids.shape == (nm,)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki, gid: (mi, ki)),
            pl.BlockSpec((1, block_k, block_n),
                         lambda mi, ni, ki, gid: (gid[mi], ki, ni)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki, gid: (mi, ni)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    kernel = functools.partial(_gmm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, N), lhs.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tile_group_ids, lhs, rhs)
