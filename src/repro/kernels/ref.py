"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately the *naive* formulations — full softmax attention,
strictly sequential recurrences, per-group matmul loops — so kernel tests
compare the optimized tilings against unambiguous semantics.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q: [B,Hq,Sq,D]; k,v: [B,Hkv,Skv,D] -> [B,Hq,Sq,D] (float32 math)."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qf = q.astype(jnp.float32) * (D ** -0.5)
    qf = qf.reshape(B, Hkv, G, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qf, k.astype(jnp.float32))
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = qpos[:, None] >= jnp.arange(Skv)[None, :]
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)


def rwkv6_ref(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
              u: jax.Array) -> jax.Array:
    """Sequential WKV6.  r,k,v,w: [B,H,S,D]; u: [H,D] -> [B,H,S,D] (f32)."""
    B, H, S, D = r.shape

    def step(state, xs):
        r_t, k_t, v_t, w_t = xs                       # [B,H,D]
        kv = k_t[..., :, None] * v_t[..., None, :]    # [B,H,Dk,Dv]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., None] * kv)
        state = w_t[..., None] * state + kv
        return state, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 2, 0)
               for t in (r, k, v, w))
    _, ys = jax.lax.scan(step, jnp.zeros((B, H, D, D), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 2)


def mamba_ref(A: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
              x: jax.Array) -> jax.Array:
    """Sequential selective scan.

    A: [di,N]; dt,x: [B,S,di]; b,c: [B,S,N] -> y [B,S,di] (float32).
    """
    B, S, di = x.shape
    N = A.shape[1]

    def step(h, xs):
        dt_t, b_t, c_t, x_t = xs
        dA = jnp.exp(dt_t[..., None] * A)
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (dt, b, c, x))
    _, ys = jax.lax.scan(step, jnp.zeros((B, di, N), jnp.float32), xs)
    return jnp.moveaxis(ys, 0, 1)


def gmm_ref(lhs: jax.Array, rhs: jax.Array,
            group_sizes: jax.Array) -> jax.Array:
    """Grouped matmul.  lhs: [M,K] rows sorted by group; rhs: [G,K,N].

    Row m belongs to group g iff offsets[g] <= m < offsets[g+1].
    """
    M = lhs.shape[0]
    G = rhs.shape[0]
    starts = jnp.concatenate([jnp.zeros((1,), group_sizes.dtype),
                              jnp.cumsum(group_sizes)])[:-1]
    row_group = jnp.sum(jnp.arange(M)[:, None]
                        >= (starts + group_sizes)[None, :], axis=1)
    row_group = jnp.clip(row_group, 0, G - 1)
    picked = rhs[row_group]                       # [M, K, N]
    return jnp.einsum("mk,mkn->mn", lhs.astype(jnp.float32),
                      picked.astype(jnp.float32)).astype(lhs.dtype)
