"""Version-tolerant aliases over ``jax.experimental.pallas.tpu``.

The TPU compiler-params dataclass is spelled ``TPUCompilerParams`` on
older jax releases and ``CompilerParams`` on newer ones; the CI matrix
covers both spellings, so kernels import :data:`CompilerParams` from
here instead of hard-coding either name.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
