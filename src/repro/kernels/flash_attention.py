"""Pallas TPU flash attention (GQA, causal, online softmax).

Tiling: grid = (B * Hq, Sq/block_q, Skv/block_k); the kv axis is the
innermost ("arbitrary" semantics) so the [block_q, D] accumulator, row max
and row sum live in VMEM scratch across kv iterations.  Q/K/V tiles are
MXU-aligned ([block, 128-multiple head dim]); softmax statistics are f32.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  q_offset: int, num_kv_blocks: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale           # [bq, D]
    k = k_ref[0].astype(jnp.float32)                   # [bk, D]
    v = v_ref[0].astype(jnp.float32)                   # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]
    if causal:
        qpos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = ki * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

    m_prev = m_scr[...]                                # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)   # fully-masked rows -> zeros
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [B,Hq,Sq,D]; k,v: [B,Hkv,Skv,D] -> [B,Hq,Sq,D]."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Skv)
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    nq, nk = Sq // block_q, Skv // block_k

    qr = q.reshape(B * Hq, Sq, D)
    kr = k.reshape(B * Hkv, Skv, D)
    vr = v.reshape(B * Hkv, Skv, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        # GQA: q head bh -> kv head (bh % Hq) // G within the same batch
        b = bh // Hq
        h = (bh % Hq) // G
        return (b * Hkv + h, ki, 0)

    kernel = functools.partial(
        _flash_kernel, scale=D ** -0.5, causal=causal, block_q=block_q,
        block_k=block_k, q_offset=q_offset, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_map),
            pl.BlockSpec((1, block_k, D), kv_map),
            pl.BlockSpec((1, block_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, Sq, D)
