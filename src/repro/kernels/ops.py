"""Jit'd public wrappers for the Pallas kernels.

On a CPU backend (this container) kernels run in ``interpret=True`` mode —
the kernel body executes as jnp ops per grid cell, which validates the
tiling/masking logic exactly.  On TPU the same call sites compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rwkv6_scan as _rw
from repro.kernels import mamba_scan as _mb
from repro.kernels import gmm as _gmm


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128):
    """q: [B,Sq,Hq,D]; k,v: [B,Skv,Hkv,D] -> [B,Sq,Hq,D] (model layout)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa.flash_attention(qt, kt, vt, causal=causal, q_offset=q_offset,
                            block_q=block_q, block_k=block_k,
                            interpret=_interpret_default())
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk",))
def rwkv6_scan(r, k, v, w, u, *, chunk: int = _rw.DEFAULT_CHUNK):
    """r,k,v,w: [B,S,H,D]; u: [H,D] -> [B,S,H,D] (model layout)."""
    tr = lambda t: jnp.swapaxes(t, 1, 2)
    o = _rw.rwkv6_scan(tr(r), tr(k), tr(v), tr(w), u, chunk=chunk,
                       interpret=_interpret_default())
    return jnp.swapaxes(o, 1, 2)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def mamba_scan(A, dt, b, c, x, *, chunk: int = _mb.DEFAULT_CHUNK,
               block_d: int = _mb.DEFAULT_BLOCK_D):
    """A: [di,N]; dt,x: [B,S,di]; b,c: [B,S,N] -> y [B,S,di]."""
    return _mb.mamba_scan(A, dt, b, c, x, chunk=chunk, block_d=block_d,
                          interpret=_interpret_default())


@functools.partial(jax.jit, static_argnames=("block_m", "block_k", "block_n"))
def gmm_padded(lhs, rhs, tile_group_ids, *,
               block_m: int = _gmm.DEFAULT_BLOCK_M,
               block_k: int = _gmm.DEFAULT_BLOCK_K,
               block_n: int = _gmm.DEFAULT_BLOCK_N):
    return _gmm.gmm(lhs, rhs, tile_group_ids, block_m=block_m,
                    block_k=block_k, block_n=block_n,
                    interpret=_interpret_default())


def gmm_sorted(lhs, rhs, group_sizes, *, block_m: int = _gmm.DEFAULT_BLOCK_M):
    """Convenience: pad each group's rows to block_m and run the kernel.

    lhs rows must already be sorted by group.  Returns [M, N] unpadded.
    Group sizes must be concrete (host-side routing metadata).
    """
    import numpy as np
    sizes = np.asarray(group_sizes)
    G = rhs.shape[0]
    starts = np.concatenate([[0], np.cumsum(sizes)])
    padded = [int(-(-s // block_m) * block_m) if s else 0 for s in sizes]
    total = sum(padded) or block_m
    out_rows = []
    tile_ids = []
    lhs_p = jnp.zeros((total, lhs.shape[1]), lhs.dtype)
    off = 0
    for g in range(G):
        if sizes[g] == 0:
            continue
        seg = lhs[starts[g]:starts[g + 1]]
        lhs_p = jax.lax.dynamic_update_slice(lhs_p, seg, (off, 0))
        tile_ids += [g] * (padded[g] // block_m)
        out_rows.append((off, int(sizes[g]), starts[g]))
        off += padded[g]
    if not tile_ids:
        tile_ids = [0]
    y_p = gmm_padded(lhs_p, rhs, jnp.asarray(tile_ids, jnp.int32),
                     block_m=block_m)
    out = jnp.zeros((lhs.shape[0], rhs.shape[2]), lhs.dtype)
    for off, n, start in out_rows:
        out = jax.lax.dynamic_update_slice(
            out, jax.lax.dynamic_slice(y_p, (off, 0), (n, rhs.shape[2])),
            (start, 0))
    return out
