"""Encoder-decoder backbone (SeamlessM4T-medium shape).

Encoder: bidirectional self-attention blocks over stub frame embeddings.
Decoder: causal self-attention + cross-attention to encoder states.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import (
    Params, Axes, rmsnorm_init, rmsnorm, mlp_init, mlp_axes, mlp_apply,
)
from repro.models.attention import (
    attention_init, attention_axes, attention_apply, attention_prefill,
    attention_decode, _project_qkv, _attend,
)


# ---------------------------------------------------------------------------
# encoder block (bidirectional)
# ---------------------------------------------------------------------------

def enc_block_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attention_init(cfg, k1),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp_init(cfg, k2),
    }


def enc_block_axes(cfg: ModelConfig) -> Axes:
    return {"ln1": ("embed",), "attn": attention_axes(cfg),
            "ln2": ("embed",), "mlp": mlp_axes(cfg)}


def enc_block_apply(cfg: ModelConfig, p: Params, h: jax.Array,
                    positions: jax.Array) -> jax.Array:
    a = attention_apply(cfg, p["attn"], rmsnorm(h, p["ln1"], cfg.rms_eps),
                        positions, causal=False)
    h = h + a
    return h + mlp_apply(cfg, p["mlp"], rmsnorm(h, p["ln2"], cfg.rms_eps))


# ---------------------------------------------------------------------------
# decoder block (causal self-attn + cross-attn)
# ---------------------------------------------------------------------------

def dec_block_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "self_attn": attention_init(cfg, k1),
        "ln_x": rmsnorm_init(cfg.d_model, dt),
        "cross_attn": attention_init(cfg, k2, cross=True),
        "ln2": rmsnorm_init(cfg.d_model, dt),
        "mlp": mlp_init(cfg, k3),
    }


def dec_block_axes(cfg: ModelConfig) -> Axes:
    return {
        "ln1": ("embed",), "self_attn": attention_axes(cfg),
        "ln_x": ("embed",), "cross_attn": attention_axes(cfg),
        "ln2": ("embed",), "mlp": mlp_axes(cfg),
    }


def dec_block_apply(cfg: ModelConfig, p: Params, h: jax.Array,
                    positions: jax.Array, enc_h: jax.Array,
                    enc_positions: jax.Array) -> jax.Array:
    a = attention_apply(cfg, p["self_attn"],
                        rmsnorm(h, p["ln1"], cfg.rms_eps),
                        positions, causal=True)
    h = h + a
    x = attention_apply(cfg, p["cross_attn"],
                        rmsnorm(h, p["ln_x"], cfg.rms_eps),
                        positions, causal=False, kv_x=enc_h,
                        kv_positions=enc_positions)
    h = h + x
    return h + mlp_apply(cfg, p["mlp"], rmsnorm(h, p["ln2"], cfg.rms_eps))


def dec_block_prefill(cfg: ModelConfig, p: Params, h: jax.Array,
                      positions: jax.Array, enc_h: jax.Array,
                      enc_positions: jax.Array,
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    a, self_kv = attention_prefill(cfg, p["self_attn"],
                                   rmsnorm(h, p["ln1"], cfg.rms_eps),
                                   positions)
    h = h + a
    # cross attention: cache encoder-side K/V so decode never re-projects
    xn = rmsnorm(h, p["ln_x"], cfg.rms_eps)
    q, ck, cv = _project_qkv(cfg, p["cross_attn"], xn, positions,
                             kv_x=enc_h, kv_positions=enc_positions)
    o = _attend(cfg, q, ck, cv, causal=False)
    B, S = h.shape[:2]
    o = o.reshape(B, S, cfg.q_dim)
    dtc = jnp.dtype(cfg.dtype)
    h = h + jnp.einsum("bsh,hd->bsd", o, p["cross_attn"]["wo"].astype(dtc))
    h = h + mlp_apply(cfg, p["mlp"], rmsnorm(h, p["ln2"], cfg.rms_eps))
    Senc = enc_h.shape[1]
    cache = {
        "k": self_kv["k"], "v": self_kv["v"],
        "xk": ck.reshape(B, Senc, cfg.kv_dim),
        "xv": cv.reshape(B, Senc, cfg.kv_dim),
    }
    return h, cache


def dec_block_decode(cfg: ModelConfig, p: Params, h: jax.Array,
                     positions: jax.Array, cache: Dict[str, jax.Array],
                     index: jax.Array,
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    a, ck, cv = attention_decode(cfg, p["self_attn"],
                                 rmsnorm(h, p["ln1"], cfg.rms_eps),
                                 positions, cache["k"], cache["v"], index)
    h = h + a
    # cross attention against the cached encoder K/V (no causal mask)
    dtc = jnp.dtype(cfg.dtype)
    xn = rmsnorm(h, p["ln_x"], cfg.rms_eps)
    B = h.shape[0]
    Senc = cache["xk"].shape[1]
    q, _, _ = _project_qkv(cfg, p["cross_attn"], xn, positions)
    kk = cache["xk"].reshape(B, Senc, cfg.num_kv_heads, cfg.head_dim)
    vv = cache["xv"].reshape(B, Senc, cfg.num_kv_heads, cfg.head_dim)
    o = _attend(cfg, q, kk, vv, causal=False)
    o = o.reshape(B, 1, cfg.q_dim)
    h = h + jnp.einsum("bsh,hd->bsd", o, p["cross_attn"]["wo"].astype(dtc))
    h = h + mlp_apply(cfg, p["mlp"], rmsnorm(h, p["ln2"], cfg.rms_eps))
    return h, {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}
