"""GQA attention: init, train/prefill forward (chunked, flash-style), decode.

Two implementations share one module:
  * ``xla``    – pure jnp, q-block-chunked softmax(QK^T)V.  Fully SPMD
                 partitionable; this path is what the multi-pod dry-run
                 lowers (Pallas/Mosaic cannot target the CPU backend).
  * ``pallas`` – kernels/flash_attention.py via shard_map on real TPU
                 (validated with interpret=True in tests).

Weights are stored with FLATTENED head dims ([d_model, H*Dh]) so the tensor
dims always divide the 16-way model axis even when num_heads doesn't
(e.g. phi3's 40 heads, GQA kv=8/10) — see DESIGN.md §5.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import (
    Params, Axes, dense_init, rmsnorm_init, rmsnorm, apply_rope, apply_mrope,
)
from repro.parallel.context import shard

ATTN_CHUNK = 2048  # q-block size for the chunked XLA path


def attention_init(cfg: ModelConfig, key, *, cross: bool = False) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.q_dim), dt),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dt),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dt),
        "wo": dense_init(ks[3], (cfg.q_dim, cfg.d_model), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dt)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dt)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dt)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.head_dim, dt)
        p["k_norm"] = rmsnorm_init(cfg.head_dim, dt)
    del cross
    return p


def attention_axes(cfg: ModelConfig) -> Axes:
    a: Axes = {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv"),
        "wv": ("embed", "kv"),
        "wo": ("heads", "embed"),
    }
    if cfg.qkv_bias:
        a["bq"] = ("heads",)
        a["bk"] = ("kv",)
        a["bv"] = ("kv",)
    if cfg.qk_norm:
        a["q_norm"] = (None,)
        a["k_norm"] = (None,)
    return a


# ---------------------------------------------------------------------------
# projections
# ---------------------------------------------------------------------------

def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array,
                 positions: Optional[jax.Array],
                 kv_x: Optional[jax.Array] = None,
                 kv_positions: Optional[jax.Array] = None,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns q [B,S,Hq,Dh], k/v [B,Skv,Hkv,Dh] with RoPE + qk-norm applied."""
    dt = jnp.dtype(cfg.dtype)
    kv_x = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", kv_x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    B, S = x.shape[:2]
    Skv = kv_x.shape[1]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, Skv, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    if positions is not None and cfg.rope_theta > 0.0:
        if cfg.mrope_sections:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            kp = kv_positions if kv_positions is not None else positions
            k = apply_mrope(k, kp, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            kp = kv_positions if kv_positions is not None else positions
            k = apply_rope(k, kp, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# chunked softmax attention (XLA path)
# ---------------------------------------------------------------------------

def repeat_kv(cfg: ModelConfig, t: jax.Array) -> jax.Array:
    """[B,S,Hkv,Dh] -> [B,S,Hq,Dh].

    GQA's grouped einsum puts the (small) kv-head dim on the model axis,
    which it cannot divide (8 kv heads on a 16-way axis) — GSPMD then
    replicates the scores and inserts a per-chunk all-reduce (measured
    ~1 TB/device/step on qwen2.5-32b train, EXPERIMENTS.md §Perf).
    Expanding K/V to the full q-head count makes every attention einsum
    shard cleanly on heads at the cost of a transient repeat.
    """
    G = cfg.num_heads // cfg.num_kv_heads
    if G == 1:
        return t
    return jnp.repeat(t, G, axis=2)


def _attend_chunked(cfg: ModelConfig, q: jax.Array, k: jax.Array,
                    v: jax.Array, *, causal: bool,
                    q_offset: int = 0) -> jax.Array:
    """softmax(QK^T)V with the q axis processed in blocks via lax.scan.

    Bounds the materialized score tensor to [B,H,chunk,Skv] regardless of
    sequence length (the XLA-level analogue of flash attention's outer loop).
    q: [B,Sq,Hq,Dh]  k,v: [B,Skv,Hkv,Dh]  ->  [B,Sq,Hq,Dh]
    """
    B, Sq, Hq, Dh = q.shape
    Skv = k.shape[1]
    scale = Dh ** -0.5
    k = shard(repeat_kv(cfg, k), "batch", None, "heads_dim", None)
    v = shard(repeat_kv(cfg, v), "batch", None, "heads_dim", None)
    qg = shard(q, "batch", None, "heads_dim", None)

    def block(qb: jax.Array, qpos: jax.Array) -> jax.Array:
        # qb: [B, C, Hq, Dh]; qpos: [C] absolute positions of the q rows
        s = jnp.einsum("bchd,bshd->bchs", qb.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
        if causal:
            kpos = jnp.arange(Skv)
            mask = qpos[:, None] >= kpos[None, :]         # [C, Skv]
            s = jnp.where(mask[None, :, None, :], s, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bchs,bshd->bchd", w, v.astype(jnp.float32))
        return o.astype(q.dtype)

    if Sq <= ATTN_CHUNK:
        out = block(qg, q_offset + jnp.arange(Sq))
    else:
        assert Sq % ATTN_CHUNK == 0, (Sq, ATTN_CHUNK)
        nblk = Sq // ATTN_CHUNK
        qb = qg.reshape(B, nblk, ATTN_CHUNK, Hq, Dh)
        qb = jnp.moveaxis(qb, 1, 0)                       # [nblk, B, C, ...]

        def body(_, xs):
            qblk, i = xs
            pos = q_offset + i * ATTN_CHUNK + jnp.arange(ATTN_CHUNK)
            return None, block(qblk, pos)

        _, ob = jax.lax.scan(body, None, (qb, jnp.arange(nblk)))
        out = jnp.moveaxis(ob, 0, 1).reshape(B, Sq, Hq, Dh)
    return out.reshape(B, Sq, Hq, Dh)


def _attend(cfg: ModelConfig, q, k, v, *, causal, q_offset: int = 0):
    if cfg.attention_impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal,
                                    q_offset=q_offset)
    return _attend_chunked(cfg, q, k, v, causal=causal, q_offset=q_offset)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------

def attention_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                    positions: jax.Array, *, causal: bool = True,
                    kv_x: Optional[jax.Array] = None,
                    kv_positions: Optional[jax.Array] = None) -> jax.Array:
    """Full (train/prefill) attention.  x: [B,S,d] -> [B,S,d]."""
    dt = jnp.dtype(cfg.dtype)
    q, k, v = _project_qkv(cfg, p, x, positions, kv_x, kv_positions)
    o = _attend(cfg, q, k, v, causal=causal)
    B, S = x.shape[:2]
    o = o.reshape(B, S, cfg.q_dim)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(dt))


def attention_prefill(cfg: ModelConfig, p: Params, x: jax.Array,
                      positions: jax.Array,
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: returns output AND the (flattened-kv) cache entries."""
    dt = jnp.dtype(cfg.dtype)
    q, k, v = _project_qkv(cfg, p, x, positions)
    o = _attend(cfg, q, k, v, causal=True)
    B, S = x.shape[:2]
    out = jnp.einsum("bsh,hd->bsd", o.reshape(B, S, cfg.q_dim),
                     p["wo"].astype(dt))
    cache = {"k": k.reshape(B, S, cfg.kv_dim), "v": v.reshape(B, S, cfg.kv_dim)}
    return out, cache


def attention_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                     positions: jax.Array, cache_k: jax.Array,
                     cache_v: jax.Array, cache_index: jax.Array,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode step against a [B, Smax, kv_dim] cache.

    x: [B,1,d]; ``cache_index`` is a per-slot [B] vector (continuous
    batching admits requests with different prompt lengths).
    Returns (out [B,1,d], new_cache_k, new_cache_v).
    """
    dt = jnp.dtype(cfg.dtype)
    B = x.shape[0]
    Smax = cache_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x, positions)
    k = k.reshape(B, cfg.kv_dim).astype(cache_k.dtype)
    v = v.reshape(B, cfg.kv_dim).astype(cache_v.dtype)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, cache_index].set(k, mode="drop")
    cache_v = cache_v.at[bidx, cache_index].set(v, mode="drop")
    kk = repeat_kv(cfg, cache_k.reshape(B, Smax, cfg.num_kv_heads,
                                        cfg.head_dim))
    vv = repeat_kv(cfg, cache_v.reshape(B, Smax, cfg.num_kv_heads,
                                        cfg.head_dim))
    qg = q.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bchd,bshd->bchs", qg.astype(jnp.float32) * scale,
                   kk.astype(jnp.float32))
    # mask positions beyond each slot's index (index = this token's slot)
    valid = (jnp.arange(Smax)[None, :]
             <= cache_index[:, None])[:, None, None, :]
    s = jnp.where(valid, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bchs,bshd->bchd", w, vv.astype(jnp.float32))
    o = o.astype(dt).reshape(B, 1, cfg.q_dim)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"].astype(dt))
    return out, cache_k, cache_v
