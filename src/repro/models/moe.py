"""Mixture-of-Experts layer: top-k router + capacity-bounded scatter dispatch.

Dispatch strategy (XLA path): tokens are scattered into a fixed-capacity
[E, C, d] buffer and gathered back after the per-expert SwiGLU.  This is
O(T*k*d) in time and memory — the classic one-hot-einsum dispatch is
O(T*E*C) and does NOT scale to the 1M-token train_4k cells (it would
materialize a [1M, 128, 82k] mask).  Expert weights are stacked [E, ...]
and sharded on the "experts" logical axis (EP on the model mesh axis).

The TPU fast path is kernels/gmm.py (sort-based grouped matmul) behind
``scan_impl="pallas"``; the scatter path is what the dry-run lowers.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import Params, Axes, dense_init
from repro.parallel.context import shard

AUX_LOSS_COEF = 0.01


def moe_init(cfg: ModelConfig, key) -> Params:
    m = cfg.moe
    assert m is not None
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    E, d, f = m.num_experts, cfg.d_model, m.d_ff_expert
    p: Params = {
        "router": dense_init(ks[0], (d, E), jnp.float32),
        "wi_gate": dense_init(ks[1], (E, d, f), dt, in_axis=1),
        "wi_up": dense_init(ks[2], (E, d, f), dt, in_axis=1),
        "wo": dense_init(ks[3], (E, f, d), dt, in_axis=1),
    }
    return p


def moe_axes(cfg: ModelConfig) -> Axes:
    return {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "expert_mlp"),
        "wi_up": ("experts", "embed", "expert_mlp"),
        "wo": ("experts", "expert_mlp", "embed"),
    }


def _capacity(m, num_tokens: int) -> int:
    c = int(m.capacity_factor * num_tokens * m.experts_per_token
            / m.num_experts)
    return max(c, m.experts_per_token)


def moe_apply(cfg: ModelConfig, p: Params, x: jax.Array,
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,S,d] -> (out [B,S,d], aux_loss scalar).

    Batches beyond ``moe.chunk_tokens`` are processed in token chunks via
    lax.scan: the [E, C, d] dispatch working set stays fixed no matter how
    long the prefill is (32k x 32 = 1M tokens would otherwise materialize
    a ~64 GB dispatch buffer — EXPERIMENTS.md §Perf iteration 2).
    """
    m = cfg.moe
    assert m is not None
    B, S, d = x.shape
    T = B * S
    Tc = m.chunk_tokens
    xf = x.reshape(T, d)
    if Tc and T > Tc and T % Tc == 0:
        nc = T // Tc

        def body(aux, xc):
            yc, a = _moe_tokens(cfg, p, xc)
            return aux + a, yc

        aux, ys = jax.lax.scan(body, jnp.zeros((), jnp.float32),
                               xf.reshape(nc, Tc, d))
        return ys.reshape(B, S, d), aux / nc
    out, aux = _moe_tokens(cfg, p, xf)
    return out.reshape(B, S, d), aux


def _moe_tokens(cfg: ModelConfig, p: Params, xf: jax.Array,
                ) -> Tuple[jax.Array, jax.Array]:
    """Route + dispatch + expert FFN + combine for a flat [T, d] slab."""
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    T, d = xf.shape
    E, k = m.num_experts, m.experts_per_token
    C = _capacity(m, T)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # [T, E]
    gate_w, ids = jax.lax.top_k(probs, k)                      # [T, k]
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)  # renormalize

    # ---- load-balancing auxiliary loss (Switch-style) ------------------
    me = jnp.mean(probs, axis=0)                               # [E]
    onehot_topk = jax.nn.one_hot(ids, E, dtype=jnp.float32)    # [T, k, E]
    ce = jnp.mean(jnp.sum(onehot_topk, axis=1), axis=0)        # frac routed
    aux = AUX_LOSS_COEF * E * jnp.sum(me * ce) / k

    # ---- position-in-expert via cumsum over the flattened assignments --
    ids_flat = ids.reshape(T * k)                              # token-major
    oh = jax.nn.one_hot(ids_flat, E, dtype=jnp.int32)          # [T*k, E]
    pos_flat = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(T * k), ids_flat]
    keep = pos_flat < C                                        # drop overflow
    pos_flat = jnp.where(keep, pos_flat, C)                    # park drops

    # ---- dispatch: scatter tokens into [E, C+1, d] (slot C = dropped) --
    # NOTE: we deliberately do NOT with_sharding_constraint the dispatch
    # buffers.  Forcing xe/ye onto the experts axis made GSPMD replicate
    # the expert einsums (useful-flops ratio 0.60 -> 0.07 on dbrx-132b);
    # left alone it emits an all-to-all EP dispatch.  Recorded as a
    # REFUTED hypothesis in EXPERIMENTS.md §Perf iteration 2.
    upd = jnp.repeat(xf.astype(dt), k, axis=0)                 # [T*k, d]
    xe = jnp.zeros((E, C + 1, d), dt)
    xe = xe.at[ids_flat, pos_flat].add(upd, mode="drop")

    # ---- per-expert SwiGLU ---------------------------------------------
    gate = jnp.einsum("ecd,edf->ecf", xe, p["wi_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xe, p["wi_up"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(gate) * up,
                    p["wo"].astype(dt))

    # ---- combine: gather back + weighted sum over k ---------------------
    back = ye[ids_flat, pos_flat]                              # [T*k, d]
    back = back * (keep[:, None] * gate_w.reshape(T * k)[:, None]).astype(dt)
    out = jnp.sum(back.reshape(T, k, d), axis=1)
    return out, aux


def moe_flops(cfg: ModelConfig, num_tokens: int) -> int:
    """Forward matmul FLOPs of one MoE layer (for roofline accounting)."""
    m = cfg.moe
    assert m is not None
    per_tok = 2 * 3 * cfg.d_model * m.d_ff_expert * m.experts_per_token
    return num_tokens * (per_tok + 2 * cfg.d_model * m.num_experts)
