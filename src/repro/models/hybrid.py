"""Jamba-style hybrid superblock: period-P interleave of Mamba and attention.

With period 8, attn_pos 4, MoE on odd positions the superblock is

    pos 0: mamba + MLP        pos 4: attention + MLP
    pos 1: mamba + MoE        pos 5: mamba + MoE
    pos 2: mamba + MLP        pos 6: mamba + MLP
    pos 3: mamba + MoE        pos 7: mamba + MoE

The model scans over ``num_layers // period`` identical superblocks, so the
HLO contains one superblock body (8 sublayers) regardless of depth.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import (
    Params, Axes, rmsnorm_init, rmsnorm, mlp_init, mlp_axes, mlp_apply,
)
from repro.models.attention import (
    attention_init, attention_axes, attention_prefill, attention_apply,
    attention_decode,
)
from repro.models.moe import moe_init, moe_axes, moe_apply
from repro.models.mamba import (
    mamba_init, mamba_axes, mamba_apply, mamba_decode, mamba_cache_init,
)


def _positions(cfg: ModelConfig) -> List[Tuple[str, str]]:
    """[(mixer, ffn)] for each position in one superblock."""
    m = cfg.moe
    out = []
    for i in range(cfg.hybrid_period):
        mixer = "attn" if i == cfg.hybrid_attn_pos else "mamba"
        is_moe = (cfg.is_moe and m is not None
                  and i % m.moe_every == m.moe_offset)
        out.append((mixer, "moe" if is_moe else "mlp"))
    return out


def superblock_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    pos = _positions(cfg)
    n_mamba = sum(1 for m, _ in pos if m == "mamba")
    n_moe = sum(1 for _, f in pos if f == "moe")
    n_mlp = len(pos) - n_moe
    ks = jax.random.split(key, 6)
    p: Params = {
        "attn": attention_init(cfg, ks[0]),
        "mamba": jax.vmap(lambda k: mamba_init(cfg, k))(
            jax.random.split(ks[1], n_mamba)),
        "mlp": jax.vmap(lambda k: mlp_init(cfg, k))(
            jax.random.split(ks[2], n_mlp)),
        "ln_mix": jnp.ones((len(pos), cfg.d_model), dt),
        "ln_ffn": jnp.ones((len(pos), cfg.d_model), dt),
    }
    if n_moe:
        p["moe"] = jax.vmap(lambda k: moe_init(cfg, k))(
            jax.random.split(ks[3], n_moe))
    return p


def superblock_axes(cfg: ModelConfig) -> Axes:
    pos = _positions(cfg)
    prep = lambda tree: jax.tree.map(
        lambda ax: ("sublayer",) + ax, tree,
        is_leaf=lambda x: isinstance(x, tuple))
    a: Axes = {
        "attn": attention_axes(cfg),
        "mamba": prep(mamba_axes(cfg)),
        "mlp": prep(mlp_axes(cfg)),
        "ln_mix": (None, "embed"),
        "ln_ffn": (None, "embed"),
    }
    if any(f == "moe" for _, f in pos):
        a["moe"] = prep(moe_axes(cfg))
    return a


def _slice_tree(tree: Params, i: int) -> Params:
    return jax.tree.map(lambda x: x[i], tree)


def superblock_apply(cfg: ModelConfig, p: Params, h: jax.Array,
                     positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Train forward through one superblock."""
    aux = jnp.zeros((), jnp.float32)
    im, io, il = 0, 0, 0
    for i, (mixer, ffn) in enumerate(_positions(cfg)):
        x = rmsnorm(h, p["ln_mix"][i], cfg.rms_eps)
        if mixer == "attn":
            h = h + attention_apply(cfg, p["attn"], x, positions, causal=True)
        else:
            h = h + mamba_apply(cfg, _slice_tree(p["mamba"], im), x)
            im += 1
        x = rmsnorm(h, p["ln_ffn"][i], cfg.rms_eps)
        if ffn == "moe":
            y, a = moe_apply(cfg, _slice_tree(p["moe"], io), x)
            io += 1
            aux = aux + a
        else:
            y = mlp_apply(cfg, _slice_tree(p["mlp"], il), x)
            il += 1
        h = h + y
    return h, aux


def superblock_prefill(cfg: ModelConfig, p: Params, h: jax.Array,
                       positions: jax.Array,
                       ) -> Tuple[jax.Array, Dict[str, jax.Array], jax.Array]:
    """Prefill: also emits the attention KV for this superblock's attn layer.

    (Mamba layers re-derive their decode state from the last tokens via the
    serving engine's state-capture prefill path; see serve/engine.py.)
    """
    aux = jnp.zeros((), jnp.float32)
    cache: Dict[str, jax.Array] = {}
    im, io, il = 0, 0, 0
    for i, (mixer, ffn) in enumerate(_positions(cfg)):
        x = rmsnorm(h, p["ln_mix"][i], cfg.rms_eps)
        if mixer == "attn":
            a, kv = attention_prefill(cfg, p["attn"], x, positions)
            h = h + a
            cache["k"], cache["v"] = kv["k"], kv["v"]
        else:
            y, st = mamba_apply(cfg, _slice_tree(p["mamba"], im), x,
                                return_state=True)
            h = h + y
            cache.setdefault("conv", []).append(st["conv"])
            cache.setdefault("ssm", []).append(st["ssm"])
            im += 1
        x = rmsnorm(h, p["ln_ffn"][i], cfg.rms_eps)
        if ffn == "moe":
            y, a = moe_apply(cfg, _slice_tree(p["moe"], io), x)
            io += 1
            aux = aux + a
        else:
            y = mlp_apply(cfg, _slice_tree(p["mlp"], il), x)
            il += 1
        h = h + y
    cache["conv"] = jnp.stack(cache["conv"])
    cache["ssm"] = jnp.stack(cache["ssm"])
    return h, cache, aux


def superblock_decode(cfg: ModelConfig, p: Params, h: jax.Array,
                      positions: jax.Array, cache: Dict[str, jax.Array],
                      index: jax.Array,
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    new_cache = dict(cache)
    im, io, il = 0, 0, 0
    for i, (mixer, ffn) in enumerate(_positions(cfg)):
        x = rmsnorm(h, p["ln_mix"][i], cfg.rms_eps)
        if mixer == "attn":
            a, ck, cv = attention_decode(cfg, p["attn"], x, positions,
                                         cache["k"], cache["v"], index)
            h = h + a
            new_cache["k"], new_cache["v"] = ck, cv
        else:
            st = {"conv": cache["conv"][im], "ssm": cache["ssm"][im]}
            y, st = mamba_decode(cfg, _slice_tree(p["mamba"], im), x, st)
            h = h + y
            new_cache["conv"] = new_cache["conv"].at[im].set(st["conv"])
            new_cache["ssm"] = new_cache["ssm"].at[im].set(st["ssm"])
            im += 1
        x = rmsnorm(h, p["ln_ffn"][i], cfg.rms_eps)
        if ffn == "moe":
            y, _ = moe_apply(cfg, _slice_tree(p["moe"], io), x)
            io += 1
        else:
            y = mlp_apply(cfg, _slice_tree(p["mlp"], il), x)
            il += 1
        h = h + y
    return h, new_cache


def hybrid_cache_init(cfg: ModelConfig, batch: int, max_len: int,
                      ) -> Dict[str, jax.Array]:
    nb = cfg.num_layers // cfg.hybrid_period
    n_mamba = sum(1 for m, _ in _positions(cfg) if m == "mamba")
    one = mamba_cache_init(cfg, batch)
    dt = jnp.dtype(cfg.dtype)
    return {
        "k": jnp.zeros((nb, batch, max_len, cfg.kv_dim), dt),
        "v": jnp.zeros((nb, batch, max_len, cfg.kv_dim), dt),
        "conv": jnp.zeros((nb, n_mamba) + one["conv"].shape, dt),
        "ssm": jnp.zeros((nb, n_mamba) + one["ssm"].shape, jnp.float32),
    }
