"""Mamba-1 selective SSM block (Jamba configuration, d_state=16).

XLA path: chunked sequential scan — the sequence is processed in chunks of
``CHUNK`` tokens by an outer ``lax.scan`` whose body is rematerialized, so
backward memory is bounded by chunk boundaries (the XLA-level analogue of
the Pallas chunked kernel in kernels/mamba_scan.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import Params, Axes, dense_init, rmsnorm_init, rmsnorm

CHUNK = 256


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    m = cfg.mamba
    assert m is not None
    di = m.expand * cfg.d_model
    return di, m.d_state, m.d_conv, m.resolved_dt_rank(cfg.d_model)


def mamba_init(cfg: ModelConfig, key) -> Params:
    m = cfg.mamba
    dt = jnp.dtype(cfg.param_dtype)
    di, N, K, R = _dims(cfg)
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * di), dt),
        "conv_w": dense_init(ks[1], (K, di), dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": dense_init(ks[2], (di, R + 2 * N), dt),
        "dt_proj": dense_init(ks[3], (R, di), dt),
        "dt_bias": jnp.log(jnp.expm1(  # softplus^-1 of uniform [1e-3, 1e-1]
            10 ** (jax.random.uniform(ks[4], (di,)) * 2.0 - 3.0))).astype(dt),
        "A_log": jnp.log(A),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, cfg.d_model), dt),
        "dt_norm": rmsnorm_init(R, dt),
        "b_norm": rmsnorm_init(N, dt),
        "c_norm": rmsnorm_init(N, dt),
    }


def mamba_axes(cfg: ModelConfig) -> Axes:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": (None, "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", None),
        "dt_proj": (None, "inner"),
        "dt_bias": ("inner",),
        "A_log": ("inner", None),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
        "dt_norm": (None,),
        "b_norm": (None,),
        "c_norm": (None,),
    }


def _ssm_inputs(cfg: ModelConfig, p: Params, xc: jax.Array):
    """Post-conv activations -> (dt [.,di], B [.,N], C [.,N]) float32."""
    m = cfg.mamba
    di, N, K, R = _dims(cfg)
    dbc = jnp.einsum("...d,dr->...r", xc, p["x_proj"].astype(xc.dtype))
    dt_r, b, c = jnp.split(dbc, [R, R + N], axis=-1)
    dt_r = rmsnorm(dt_r, p["dt_norm"], cfg.rms_eps)
    b = rmsnorm(b, p["b_norm"], cfg.rms_eps).astype(jnp.float32)
    c = rmsnorm(c, p["c_norm"], cfg.rms_eps).astype(jnp.float32)
    dt = jnp.einsum("...r,rd->...d", dt_r, p["dt_proj"].astype(dt_r.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, b, c


def _scan_chunk(A, dt, b, c, xs, h0):
    """Sequential selective scan over one chunk.

    A [di,N]; dt [B,C,di]; b,c [B,C,N]; xs [B,C,di]; h0 [B,di,N] -> (y, hT)
    """
    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp          # [B,di], [B,N], [B,N], [B,di]
        dA = jnp.exp(dt_t[..., None] * A)  # [B,di,N]
        dBx = (dt_t * x_t)[..., None] * b_t[:, None, :]
        h = dA * h + dBx
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    inps = (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(b, 1, 0),
            jnp.moveaxis(c, 1, 0), jnp.moveaxis(xs, 1, 0))
    hT, ys = jax.lax.scan(step, h0, inps)
    return jnp.moveaxis(ys, 0, 1), hT


def mamba_apply(cfg: ModelConfig, p: Params, x: jax.Array,
                return_state: bool = False):
    """Full-sequence forward.  x: [B,S,d] -> [B,S,d] (+ final decode state)."""
    use_kernel = cfg.scan_impl == "pallas" and not return_state
    return _mamba_apply_impl(cfg, p, x, use_kernel=use_kernel,
                             return_state=return_state)


def _mamba_apply_impl(cfg: ModelConfig, p: Params, x: jax.Array,
                      use_kernel: bool, return_state: bool = False):
    dt_ = jnp.dtype(cfg.dtype)
    di, N, K, R = _dims(cfg)
    B, S, _ = x.shape
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xi, z = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv over seq
    xpad = jnp.pad(xi, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(xpad[:, i:i + S, :] * p["conv_w"][i].astype(dt_)
             for i in range(K))
    xc = jax.nn.silu(xc + p["conv_b"].astype(dt_))
    dt, b, c = _ssm_inputs(cfg, p, xc)
    A = -jnp.exp(p["A_log"])                        # [di, N]
    xf = xc.astype(jnp.float32)

    hT = None
    if use_kernel:
        from repro.kernels import ops as kops
        y = kops.mamba_scan(A, dt, b, c, xf)
    else:
        nc = max(S // CHUNK, 1)
        cs = S // nc
        assert S % nc == 0

        def chunk_body(h0, xs_chunk):
            dt_c, b_c, c_c, x_c = xs_chunk
            y, hT = _scan_chunk(A, dt_c, b_c, c_c, x_c, h0)
            return hT, y

        chunk_body = jax.checkpoint(chunk_body)
        resh = lambda t, w: jnp.moveaxis(
            t.reshape(B, nc, cs, w), 1, 0)
        xs = (resh(dt, di), resh(b, N), resh(c, N), resh(xf, di))
        h0 = jnp.zeros((B, di, N), jnp.float32)
        hT, ys = jax.lax.scan(chunk_body, h0, xs)
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)

    y = y + xf * p["D"]
    out = (y.astype(dt_) * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", out, p["out_proj"].astype(dt_))
    if return_state:
        assert hT is not None, "return_state requires the XLA scan path"
        conv_tail = xi[:, S - (K - 1):, :] if S >= K - 1 else jnp.pad(
            xi, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, {"conv": conv_tail, "ssm": hT}
    return out


# ---------------------------------------------------------------------------
# decode (single token, carried state)
# ---------------------------------------------------------------------------

def mamba_cache_init(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    di, N, K, _ = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, K - 1, di), jnp.dtype(cfg.dtype)),
        "ssm": jnp.zeros((batch, di, N), jnp.float32),
    }


def mamba_decode(cfg: ModelConfig, p: Params, x: jax.Array,
                 cache: Dict[str, jax.Array],
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B,1,d] -> ([B,1,d], new cache)."""
    dt_ = jnp.dtype(cfg.dtype)
    di, N, K, R = _dims(cfg)
    B = x.shape[0]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    xi, z = jnp.split(xz, 2, axis=-1)              # [B,1,di]
    window = jnp.concatenate([cache["conv"], xi], axis=1)   # [B,K,di]
    # same left-to-right bf16 accumulation order as the full-sequence conv
    xc = sum(window[:, i, :] * p["conv_w"][i].astype(dt_) for i in range(K))
    xc = jax.nn.silu(xc + p["conv_b"].astype(dt_))[:, None, :]
    dt, b, c = _ssm_inputs(cfg, p, xc)             # [B,1,*]
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[:, 0, :, None] * A)            # [B,di,N]
    dBx = (dt[:, 0] * xc[:, 0].astype(jnp.float32))[..., None] \
        * b[:, 0][:, None, :]
    h = dA * cache["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0])
    y = y + xc[:, 0].astype(jnp.float32) * p["D"]
    out = (y[:, None, :].astype(dt_) * jax.nn.silu(z))
    out = jnp.einsum("bsi,id->bsd", out, p["out_proj"].astype(dt_))
    return out, {"conv": window[:, 1:, :], "ssm": h}
