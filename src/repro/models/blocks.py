"""Decoder transformer block (dense MLP or MoE) shared by dense/moe/vlm."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import (
    Params, Axes, rmsnorm_init, rmsnorm, mlp_init, mlp_axes, mlp_apply,
)
from repro.models.attention import (
    attention_init, attention_axes, attention_apply, attention_prefill,
    attention_decode,
)
from repro.models.moe import moe_init, moe_axes, moe_apply


def block_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    p: Params = {
        "ln1": rmsnorm_init(cfg.d_model, dt),
        "attn": attention_init(cfg, k1),
        "ln2": rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(cfg, k2)
    else:
        p["mlp"] = mlp_init(cfg, k2)
    return p


def block_axes(cfg: ModelConfig) -> Axes:
    a: Axes = {"ln1": ("embed",), "attn": attention_axes(cfg),
               "ln2": ("embed",)}
    if cfg.is_moe:
        a["moe"] = moe_axes(cfg)
    else:
        a["mlp"] = mlp_axes(cfg)
    return a


def _ffn(cfg: ModelConfig, p: Params, h: jax.Array,
         ) -> Tuple[jax.Array, jax.Array]:
    x = rmsnorm(h, p["ln2"], cfg.rms_eps)
    if cfg.is_moe:
        y, aux = moe_apply(cfg, p["moe"], x)
    else:
        y, aux = mlp_apply(cfg, p["mlp"], x), jnp.zeros((), jnp.float32)
    return h + y, aux


def block_apply(cfg: ModelConfig, p: Params, h: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Train/eval full-sequence forward.  h: [B,S,d] -> (h, aux_loss)."""
    a = attention_apply(cfg, p["attn"], rmsnorm(h, p["ln1"], cfg.rms_eps),
                        positions, causal=True)
    return _ffn(cfg, p, h + a)


def block_prefill(cfg: ModelConfig, p: Params, h: jax.Array,
                  positions: jax.Array,
                  ) -> Tuple[jax.Array, Dict[str, jax.Array], jax.Array]:
    a, cache = attention_prefill(cfg, p["attn"],
                                 rmsnorm(h, p["ln1"], cfg.rms_eps), positions)
    h, aux = _ffn(cfg, p, h + a)
    return h, cache, aux


def block_decode(cfg: ModelConfig, p: Params, h: jax.Array,
                 positions: jax.Array, cache_k: jax.Array,
                 cache_v: jax.Array, index: jax.Array,
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    a, ck, cv = attention_decode(cfg, p["attn"],
                                 rmsnorm(h, p["ln1"], cfg.rms_eps),
                                 positions, cache_k, cache_v, index)
    h, _ = _ffn(cfg, p, h + a)
    return h, ck, cv
