"""RWKV6 (Finch) block: time-mixing with data-dependent decay + channel-mix.

Recurrence per head (Dk = Dv = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: [Dk, Dv])
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

XLA path: chunked sequential scan (remat per chunk).  TPU fast path:
kernels/rwkv6_scan.py.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig
from repro.models.layers import (
    Params, Axes, dense_init, rmsnorm_init, rmsnorm,
)

CHUNK = 64   # chunked-parallel form materializes [B,C,C,H,D] per chunk
_MIX_COMPONENTS = 5  # w, k, v, r, g


def _dims(cfg: ModelConfig) -> Tuple[int, int]:
    c = cfg.rwkv
    assert c is not None
    H = cfg.d_model // c.head_dim
    return H, c.head_dim


def rwkv_init(cfg: ModelConfig, key) -> Params:
    c = cfg.rwkv
    dt = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 12)
    return {
        "ln1": rmsnorm_init(d, dt),
        "ln2": rmsnorm_init(d, dt),
        # --- time mixing ------------------------------------------------
        "mu_base": jax.random.uniform(ks[0], (d,), dt, 0.0, 1.0),
        "mu": jax.random.uniform(ks[1], (_MIX_COMPONENTS, d), dt, 0.0, 1.0),
        "mix_w1": dense_init(ks[2], (d, _MIX_COMPONENTS * c.mix_lora), dt),
        "mix_w2": dense_init(ks[3], (_MIX_COMPONENTS, c.mix_lora, d), dt,
                             in_axis=1),
        "decay_base": (jax.random.uniform(ks[4], (d,), jnp.float32)
                       * 2.0 - 6.0),
        "decay_w1": dense_init(ks[5], (d, c.decay_lora), dt),
        "decay_w2": dense_init(ks[6], (c.decay_lora, d), dt),
        "u": jax.random.uniform(ks[7], (d,), jnp.float32, -1.0, 1.0),
        "wr": dense_init(ks[8], (d, d), dt),
        "wk": dense_init(ks[9], (d, d), dt),
        "wv": dense_init(ks[10], (d, d), dt),
        "wg": dense_init(ks[11], (d, d), dt),
        "wo": dense_init(jax.random.fold_in(key, 101), (d, d), dt),
        "ln_x": rmsnorm_init(d, dt),
        # --- channel mixing ----------------------------------------------
        "cmu_k": jax.random.uniform(jax.random.fold_in(key, 102), (d,), dt),
        "cmu_r": jax.random.uniform(jax.random.fold_in(key, 103), (d,), dt),
        "cw_k": dense_init(jax.random.fold_in(key, 104), (d, cfg.d_ff), dt),
        "cw_v": dense_init(jax.random.fold_in(key, 105), (cfg.d_ff, d), dt),
        "cw_r": dense_init(jax.random.fold_in(key, 106), (d, d), dt),
    }


def rwkv_axes(cfg: ModelConfig) -> Axes:
    return {
        "ln1": ("embed",), "ln2": ("embed",),
        "mu_base": ("embed",), "mu": (None, "embed"),
        "mix_w1": ("embed", None), "mix_w2": (None, None, "embed"),
        "decay_base": ("embed",),
        "decay_w1": ("embed", None), "decay_w2": (None, "embed"),
        "u": ("embed",),
        "wr": ("embed", "heads"), "wk": ("embed", "heads"),
        "wv": ("embed", "heads"), "wg": ("embed", "heads"),
        "wo": ("heads", "embed"),
        "ln_x": ("embed",),
        "cmu_k": ("embed",), "cmu_r": ("embed",),
        "cw_k": ("embed", "mlp"), "cw_v": ("mlp", "embed"),
        "cw_r": ("embed", "embed2"),
    }


# ---------------------------------------------------------------------------
# time mixing
# ---------------------------------------------------------------------------

def _ddlerp(cfg: ModelConfig, p: Params, x: jax.Array, x_prev: jax.Array):
    """Data-dependent token-shift lerp -> (mw, mk, mv, mr, mg)."""
    c = cfg.rwkv
    sx = x_prev - x
    base = x + sx * p["mu_base"].astype(x.dtype)
    lo = jnp.tanh(jnp.einsum("...d,dr->...r", base,
                             p["mix_w1"].astype(x.dtype)))
    lo = lo.reshape(*lo.shape[:-1], _MIX_COMPONENTS, c.mix_lora)
    off = jnp.einsum("...cr,crd->...cd", lo, p["mix_w2"].astype(x.dtype))
    mus = p["mu"].astype(x.dtype) + off            # [..., 5, d]
    mixed = x[..., None, :] + sx[..., None, :] * mus
    return tuple(mixed[..., i, :] for i in range(_MIX_COMPONENTS))


def _decay(cfg: ModelConfig, p: Params, mw: jax.Array) -> jax.Array:
    """Per-channel decay w_t in (0,1): exp(-exp(base + lora(mw)))."""
    lo = jnp.tanh(jnp.einsum("...d,dr->...r", mw,
                             p["decay_w1"].astype(mw.dtype)))
    dd = jnp.einsum("...r,rd->...d", lo, p["decay_w2"].astype(mw.dtype))
    return jnp.exp(-jnp.exp(p["decay_base"] + dd.astype(jnp.float32)))


def _wkv_chunk(r, k, v, w, u, S0):
    """Sequential WKV over one chunk (reference form).

    r,k,v: [B,C,H,D]; w: [B,C,H,D] decay; u: [H,D]; S0: [B,H,D,D]
    returns (y [B,C,H,D], S_T)
    """
    def step(S, inp):
        r_t, k_t, v_t, w_t = inp                       # [B,H,D]
        kv = k_t[..., :, None] * v_t[..., None, :]     # [B,H,Dk,Dv]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + u[..., None] * kv)
        S = w_t[..., None] * S + kv
        return S, y

    inps = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    ST, ys = jax.lax.scan(step, S0, inps)
    return jnp.moveaxis(ys, 0, 1), ST


def _wkv_chunk_parallel(r, k, v, w, u, S0):
    """Chunked-matmul WKV (the Pallas kernel's math in jnp, DESIGN.md §8).

    Replaces the per-token scan: the sequential form round-trips the
    [B,H,D,D] state through HBM every token (the dominant memory term of
    the rwkv6 train cell — EXPERIMENTS.md §Perf iteration 3); this form
    touches the state once per chunk and turns the recurrence into MXU
    matmuls.  All exponentials have non-positive arguments.
    """
    logw = jnp.log(jnp.maximum(w, 1e-37))              # [B,C,H,D]
    L = jnp.cumsum(logw, axis=1)
    L_prev = L - logw
    C = r.shape[1]

    # inter-chunk: r decayed to chunk start, applied to carried state
    y = jnp.einsum("bthk,bhkv->bthv", r * jnp.exp(L_prev), S0)

    # intra-chunk: A[t,s] = sum_d r_t k_s e^{L_prev[t]-L[s]}  (s < t)
    expo = L_prev[:, :, None] - L[:, None, :]          # [B,C,C,H,D]
    tri = (jnp.arange(C)[:, None] > jnp.arange(C)[None, :])
    gated = jnp.where(tri[None, :, :, None, None], jnp.exp(expo), 0.0)
    A = jnp.einsum("bthd,bshd,btshd->btsh", r, k, gated)
    diag = jnp.einsum("bthd,hd,bthd->bth", r, u, k)    # bonus term
    A = A + diag[:, :, None, :] * jnp.eye(C)[None, :, :, None]
    y = y + jnp.einsum("btsh,bshv->bthv", A, v)

    # state update: S' = diag(e^{L_C}) S0 + sum_s (k_s e^{L_C-L_s})^T v_s
    L_total = L[:, -1:]                                # [B,1,H,D]
    k_dec = k * jnp.exp(L_total - L)
    ST = (jnp.exp(L_total[:, 0])[..., None] * S0
          + jnp.einsum("bshk,bshv->bhkv", k_dec, v))
    return y, ST


def rwkv_time_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                  x_prev: jax.Array, return_state: bool = False):
    """Full-sequence time mixing.  x: [B,S,d]; x_prev: x shifted right."""
    H, D = _dims(cfg)
    B, S, d = x.shape
    mw, mk, mv, mr, mg = _ddlerp(cfg, p, x, x_prev)
    dt = x.dtype
    r = jnp.einsum("bsd,dh->bsh", mr, p["wr"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", mk, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", mv, p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bsd,dh->bsh", mg, p["wg"].astype(dt)))
    w = _decay(cfg, p, mw)                         # [B,S,d] float32

    rs = r.reshape(B, S, H, D).astype(jnp.float32)
    ks = k.reshape(B, S, H, D).astype(jnp.float32)
    vs = v.reshape(B, S, H, D).astype(jnp.float32)
    ws = w.reshape(B, S, H, D)
    u = p["u"].reshape(H, D)

    ST = None
    if cfg.scan_impl == "pallas" and not return_state:
        from repro.kernels import ops as kops
        y = kops.rwkv6_scan(rs, ks, vs, ws, u)
    else:
        nc = max(S // CHUNK, 1)
        cs = S // nc
        assert S % nc == 0
        chunk_fn = (_wkv_chunk if cfg.scan_impl == "xla_seq"
                    else _wkv_chunk_parallel)

        def chunk_body(S0, xs):
            rc, kc, vc, wc = xs
            y, ST = chunk_fn(rc, kc, vc, wc, u, S0)
            return ST, y

        chunk_body = jax.checkpoint(chunk_body)
        resh = lambda t: jnp.moveaxis(t.reshape(B, nc, cs, H, D), 1, 0)
        S0 = jnp.zeros((B, H, D, D), jnp.float32)
        ST, ys = jax.lax.scan(chunk_body, S0,
                              (resh(rs), resh(ks), resh(vs), resh(ws)))
        y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, D)

    y = y.reshape(B, S, d).astype(dt)
    y = rmsnorm(y, p["ln_x"], cfg.rms_eps) * g
    out = jnp.einsum("bsh,hd->bsd", y, p["wo"].astype(dt))
    if return_state:
        return out, ST
    return out


def rwkv_channel_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                     x_prev: jax.Array) -> jax.Array:
    dt = x.dtype
    sx = x_prev - x
    xk = x + sx * p["cmu_k"].astype(dt)
    xr = x + sx * p["cmu_r"].astype(dt)
    kk = jnp.square(jax.nn.relu(
        jnp.einsum("bsd,df->bsf", xk, p["cw_k"].astype(dt))))
    kv = jnp.einsum("bsf,fd->bsd", kk, p["cw_v"].astype(dt))
    return jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", xr, p["cw_r"].astype(dt))) * kv


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def rwkv_cache_init(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    H, D = _dims(cfg)
    d, dt = cfg.d_model, jnp.dtype(cfg.dtype)
    return {
        "tshift": jnp.zeros((batch, d), dt),
        "cshift": jnp.zeros((batch, d), dt),
        "wkv": jnp.zeros((batch, H, D, D), jnp.float32),
    }


def rwkv_decode_time(cfg: ModelConfig, p: Params, x: jax.Array,
                     cache: Dict[str, jax.Array],
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token time-mix step.  x: [B,1,d] (post-ln1 input)."""
    H, D = _dims(cfg)
    B, _, d = x.shape
    xt = x[:, 0, :]
    mw, mk, mv, mr, mg = _ddlerp(cfg, p, xt, cache["tshift"])
    dt = x.dtype
    r = jnp.einsum("bd,dh->bh", mr, p["wr"].astype(dt))
    k = jnp.einsum("bd,dh->bh", mk, p["wk"].astype(dt))
    v = jnp.einsum("bd,dh->bh", mv, p["wv"].astype(dt))
    g = jax.nn.silu(jnp.einsum("bd,dh->bh", mg, p["wg"].astype(dt)))
    w = _decay(cfg, p, mw)
    rs = r.reshape(B, H, D).astype(jnp.float32)
    ks = k.reshape(B, H, D).astype(jnp.float32)
    vs = v.reshape(B, H, D).astype(jnp.float32)
    ws = w.reshape(B, H, D)
    u = p["u"].reshape(H, D)
    S = cache["wkv"]
    kv = ks[..., :, None] * vs[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", rs, S + u[..., None] * kv)
    S = ws[..., None] * S + kv
    y = y.reshape(B, d).astype(dt)
    y = rmsnorm(y, p["ln_x"], cfg.rms_eps) * g
    out = jnp.einsum("bh,hd->bd", y, p["wo"].astype(dt))[:, None, :]
    return out, {"tshift": xt, "cshift": cache["cshift"], "wkv": S}


def rwkv_decode_channel(cfg: ModelConfig, p: Params, x: jax.Array,
                        cshift: jax.Array,
                        ) -> Tuple[jax.Array, jax.Array]:
    """One-token channel-mix step.  x: [B,1,d] (post-ln2 input)."""
    xt = x[:, 0, :]
    out = rwkv_channel_mix(cfg, p, xt[:, None, :], cshift[:, None, :])
    return out, xt
