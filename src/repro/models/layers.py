"""Shared building blocks: norms, RoPE/M-RoPE, SwiGLU, embeddings.

All parameters are plain pytrees (nested dicts of jnp arrays).  Every module
exposes three functions:

  ``<mod>_init(cfg, key) -> params``     parameter pytree for ONE layer
  ``<mod>_axes(cfg) -> axes``            matching pytree of logical-axis tuples
  ``<mod>_apply(cfg, params, ...)``      forward

Logical axis names (mapped to mesh axes by ``repro.parallel.sharding``):
  "vocab"   – embedding/unembedding vocabulary dim
  "embed"   – d_model dim
  "heads"   – flattened q projection dim (num_heads * head_dim)
  "kv"      – flattened kv projection dim (num_kv_heads * head_dim)
  "mlp"     – feed-forward hidden dim
  "experts" – MoE expert dim
  "inner"   – mamba/rwkv inner dim
  "layers"  – stacked-layer leading axis (never sharded)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ModelConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    """Truncated-normal fan-in init (MaxText-style)."""
    fan_in = shape[in_axis]
    std = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype) -> jax.Array:
    return jnp.ones((d,), dtype)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2] (float32)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate ``x`` [..., S, H, D] by per-token ``positions`` [..., S]."""
    if theta <= 0.0:  # NoPE (Jamba attention layers)
        return x
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)                    # [half]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]                        # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, ...]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): ``positions`` is [3, ..., S] (t, h, w).

    Frequency index i in [0, head_dim/2) takes its position id from the
    section it falls into: sections = (n_t, n_h, n_w), sum = head_dim/2.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                    # [half]
    # section id per frequency index
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.asarray(sections), total_repeat_length=half)
    # pos_per_freq[..., S, half]: choose t/h/w position per frequency
    pos = jnp.take(positions.astype(jnp.float32), sec_id, axis=0)  # [half, ..., S]
    pos = jnp.moveaxis(pos, 0, -1)                                 # [..., S, half]
    angles = pos * freqs
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None) -> Params:
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (cfg.d_model, d_ff), dt),
        "wi_up": dense_init(k2, (cfg.d_model, d_ff), dt),
        "wo": dense_init(k3, (d_ff, cfg.d_model), dt),
    }


def mlp_axes(cfg: ModelConfig) -> Axes:
    return {
        "wi_gate": ("embed", "mlp"),
        "wi_up": ("embed", "mlp"),
        "wo": ("mlp", "embed"),
    }


def mlp_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    gate = jnp.einsum("...d,df->...f", x, p["wi_gate"].astype(dt))
    up = jnp.einsum("...d,df->...f", x, p["wi_up"].astype(dt))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(gate) * up,
                      p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(cfg: ModelConfig, key) -> Params:
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"embedding": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size), dt)
    if cfg.frontend_embed_dim:
        # modality frontend stub projection (identity-shaped if dims equal)
        p["frontend_proj"] = dense_init(
            ks[2], (cfg.frontend_embed_dim, cfg.d_model), dt)
    return p


def embedding_axes(cfg: ModelConfig) -> Axes:
    a: Axes = {"embedding": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        a["unembed"] = ("embed", "vocab")
    if cfg.frontend_embed_dim:
        a["frontend_proj"] = (None, "embed")
    return a


def embed_tokens(cfg: ModelConfig, p: Params, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["embedding"], tokens, axis=0).astype(cfg.dtype)


def unembed(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["embedding"].T
    else:
        w = p["unembed"]
    return jnp.einsum("...d,dv->...v", h, w.astype(cfg.dtype))
