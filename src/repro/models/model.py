"""Top-level model API: init / train forward / prefill / decode, per family.

All depth is expressed as ``jax.lax.scan`` over stacked layer parameters so
the lowered HLO contains exactly one block body (plus remat policy), which
keeps 512-device compiles tractable and gives XLA a single loop to overlap
collectives around.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import (
    ModelConfig, DENSE, MOE, HYBRID, SSM, ENCDEC, VLM,
)
from repro.models import layers as L
from repro.models import blocks as B
from repro.models import hybrid as HY
from repro.models import rwkv6 as RW
from repro.models import encdec as ED
from repro.parallel.context import shard

Params = Dict[str, Any]
Batch = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key, n: int) -> Params:
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _stack_axes(axes: Any) -> Any:
    return jax.tree.map(lambda ax: ("layers",) + ax, axes,
                        is_leaf=lambda x: isinstance(x, tuple))


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ke, kb, kf = jax.random.split(key, 3)
    dt = jnp.dtype(cfg.param_dtype)
    p: Params = {
        "embed": L.embedding_init(cfg, ke),
        "final_norm": L.rmsnorm_init(cfg.d_model, dt),
    }
    if cfg.family in (DENSE, MOE, VLM):
        p["blocks"] = _stack_init(lambda k: B.block_init(cfg, k), kb,
                                  cfg.num_layers)
    elif cfg.family == HYBRID:
        nb = cfg.num_layers // cfg.hybrid_period
        p["blocks"] = _stack_init(lambda k: HY.superblock_init(cfg, k), kb, nb)
    elif cfg.family == SSM:
        p["blocks"] = _stack_init(lambda k: RW.rwkv_init(cfg, k), kb,
                                  cfg.num_layers)
    elif cfg.family == ENCDEC:
        p["enc_blocks"] = _stack_init(lambda k: ED.enc_block_init(cfg, k),
                                      kb, cfg.encoder_layers)
        p["dec_blocks"] = _stack_init(lambda k: ED.dec_block_init(cfg, k),
                                      kf, cfg.decoder_layers)
        p["enc_norm"] = L.rmsnorm_init(cfg.d_model, dt)
    else:
        raise ValueError(cfg.family)
    return p


def param_axes(cfg: ModelConfig) -> Any:
    a: Dict[str, Any] = {
        "embed": L.embedding_axes(cfg),
        "final_norm": ("embed",),
    }
    if cfg.family in (DENSE, MOE, VLM):
        a["blocks"] = _stack_axes(B.block_axes(cfg))
    elif cfg.family == HYBRID:
        a["blocks"] = _stack_axes(HY.superblock_axes(cfg))
    elif cfg.family == SSM:
        a["blocks"] = _stack_axes(RW.rwkv_axes(cfg))
    elif cfg.family == ENCDEC:
        a["enc_blocks"] = _stack_axes(ED.enc_block_axes(cfg))
        a["dec_blocks"] = _stack_axes(ED.dec_block_axes(cfg))
        a["enc_norm"] = ("embed",)
    return a


# ---------------------------------------------------------------------------
# scan helpers
# ---------------------------------------------------------------------------

def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def _scan_blocks(cfg: ModelConfig, blocks: Params, h: jax.Array,
                 positions: jax.Array, apply_fn) -> Tuple[jax.Array, jax.Array]:
    """Scan ``apply_fn(params_i, h) -> (h, aux)`` over stacked blocks.

    With ``cfg.layers_per_step = g > 1`` the stacked params are regrouped
    [L, ...] -> [L/g, g, ...] and each scan step applies g layers inside a
    single remat region: the per-layer carry stash (the dominant training
    memory term for deep dense models, EXPERIMENTS.md §Perf) shrinks g-fold
    at the cost of recomputing g layers in backward.
    """
    g = max(cfg.layers_per_step, 1)

    def body(carry, layer_params):
        h, aux = carry
        h = shard(h, "batch", None, "embed_act")
        if g == 1:
            h, a = apply_fn(layer_params, h, positions)
            aux = aux + a
        else:
            for i in range(g):
                lp = jax.tree.map(lambda x: x[i], layer_params)
                h, a = apply_fn(lp, h, positions)
                aux = aux + a
        return (h, aux), None

    if g > 1:
        L_ = next(iter(jax.tree.leaves(blocks))).shape[0]
        assert L_ % g == 0, (L_, g)
        blocks = jax.tree.map(
            lambda x: x.reshape(L_ // g, g, *x.shape[1:]), blocks)

    body = _maybe_remat(cfg, body)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), blocks)
    return h, aux


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def _embed_inputs(cfg: ModelConfig, p: Params, batch: Batch) -> jax.Array:
    """Token embeddings, with modality-frontend embeddings prepended."""
    h = L.embed_tokens(cfg, p["embed"], batch["tokens"])
    if cfg.frontend_embed_dim and "frontend" in batch:
        f = jnp.einsum("bse,ed->bsd", batch["frontend"].astype(cfg.dtype),
                       p["embed"]["frontend_proj"].astype(cfg.dtype))
        h = jnp.concatenate([f, h], axis=1)
    return shard(h, "batch", None, "embed_act")


def _logits(cfg: ModelConfig, p: Params, h: jax.Array) -> jax.Array:
    h = L.rmsnorm(h, p["final_norm"], cfg.rms_eps)
    logits = L.unembed(cfg, p["embed"], h)
    return shard(logits, "batch", None, "vocab_act")


# ---------------------------------------------------------------------------
# full-sequence forward (train / eval / prefill base)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, p: Params, batch: Batch,
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits [B,S,V], aux_loss)."""
    positions = batch["positions"]
    if cfg.family in (DENSE, MOE, VLM):
        h = _embed_inputs(cfg, p, batch)
        apply_fn = lambda lp, hh, pos: B.block_apply(cfg, lp, hh, pos)
        h, aux = _scan_blocks(cfg, p["blocks"], h, positions, apply_fn)
    elif cfg.family == HYBRID:
        h = _embed_inputs(cfg, p, batch)
        apply_fn = lambda lp, hh, pos: HY.superblock_apply(cfg, lp, hh, pos)
        h, aux = _scan_blocks(cfg, p["blocks"], h, positions, apply_fn)
    elif cfg.family == SSM:
        h = _embed_inputs(cfg, p, batch)

        def rwkv_apply(lp, hh, pos):
            del pos
            xn = L.rmsnorm(hh, lp["ln1"], cfg.rms_eps)
            xprev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
            hh = hh + RW.rwkv_time_mix(cfg, lp, xn, xprev)
            xn = L.rmsnorm(hh, lp["ln2"], cfg.rms_eps)
            xprev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
            hh = hh + RW.rwkv_channel_mix(cfg, lp, xn, xprev)
            return hh, jnp.zeros((), jnp.float32)

        h, aux = _scan_blocks(cfg, p["blocks"], h, positions, rwkv_apply)
    elif cfg.family == ENCDEC:
        enc_h, enc_positions = _encode(cfg, p, batch)
        h = L.embed_tokens(cfg, p["embed"], batch["tokens"])
        h = shard(h, "batch", None, "embed_act")

        def dec_apply(lp, hh, pos):
            hh = ED.dec_block_apply(cfg, lp, hh, pos, enc_h, enc_positions)
            return hh, jnp.zeros((), jnp.float32)

        h, aux = _scan_blocks(cfg, p["dec_blocks"], h, positions, dec_apply)
    else:
        raise ValueError(cfg.family)
    return _logits(cfg, p, h), aux


def _encode(cfg: ModelConfig, p: Params, batch: Batch,
            ) -> Tuple[jax.Array, jax.Array]:
    f = batch["frontend"].astype(cfg.dtype)
    enc_h = jnp.einsum("bse,ed->bsd", f,
                       p["embed"]["frontend_proj"].astype(cfg.dtype))
    enc_h = shard(enc_h, "batch", None, "embed_act")
    Bsz, Senc = enc_h.shape[:2]
    enc_positions = jnp.broadcast_to(jnp.arange(Senc)[None, :], (Bsz, Senc))

    def enc_apply(lp, hh, pos):
        return ED.enc_block_apply(cfg, lp, hh, pos), jnp.zeros((), jnp.float32)

    enc_h, _ = _scan_blocks(cfg, p["enc_blocks"], enc_h, enc_positions,
                            enc_apply)
    return L.rmsnorm(enc_h, p["enc_norm"], cfg.rms_eps), enc_positions


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

Z_LOSS_COEF = 1e-4


def loss_fn(cfg: ModelConfig, p: Params, batch: Batch,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward(cfg, p, batch)
    targets = batch["targets"]
    V = cfg.vocab_size
    if cfg.frontend_embed_dim and "frontend" in batch and cfg.family != ENCDEC:
        # frontend positions carry no next-token target; score text tail only
        S_text = targets.shape[1]
        logits = logits[:, -S_text:, :]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    mask = (targets >= 0).astype(jnp.float32)
    tgt = jnp.where(targets >= 0, targets, 0)
    # target log-prob via a one-hot masked reduction rather than a gather:
    # GSPMD partitions select+reduce along the (model-sharded) vocab dim,
    # while a take_along_axis gather forces an involuntary all-gather of
    # the [B,S,V] logits on every device (measured +10 GB/device on the
    # 152k-vocab archs — see EXPERIMENTS.md §Perf iteration 1).
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lf.shape, lf.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lf, 0.0), axis=-1)
    nll = (lse - ll) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll) / denom
    z = Z_LOSS_COEF * jnp.sum(jnp.square(lse) * mask) / denom
    total = ce + z + aux
    return total, {"loss": total, "ce": ce, "aux": aux, "z": z,
                   "tokens": jnp.sum(mask)}


# ---------------------------------------------------------------------------
# KV-cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Params:
    dt = jnp.dtype(cfg.dtype)
    if cfg.family in (DENSE, MOE, VLM):
        Lc = cfg.num_layers
        c = {"k": jnp.zeros((Lc, batch, max_len, cfg.kv_dim), dt),
             "v": jnp.zeros((Lc, batch, max_len, cfg.kv_dim), dt)}
    elif cfg.family == HYBRID:
        c = HY.hybrid_cache_init(cfg, batch, max_len)
    elif cfg.family == SSM:
        one = RW.rwkv_cache_init(cfg, batch)
        c = {k: jnp.zeros((cfg.num_layers,) + v.shape, v.dtype)
             for k, v in one.items()}
    elif cfg.family == ENCDEC:
        Ld = cfg.decoder_layers
        c = {"k": jnp.zeros((Ld, batch, max_len, cfg.kv_dim), dt),
             "v": jnp.zeros((Ld, batch, max_len, cfg.kv_dim), dt),
             "xk": jnp.zeros((Ld, batch, max_len, cfg.kv_dim), dt),
             "xv": jnp.zeros((Ld, batch, max_len, cfg.kv_dim), dt)}
    else:
        raise ValueError(cfg.family)
    c["index"] = jnp.zeros((batch,), jnp.int32)
    return c


def cache_logical_axes(cfg: ModelConfig, *, shard_seq: bool = False) -> Any:
    """Logical axes for the cache pytree (seq axis shardable for long ctx)."""
    seq = "kv_seq" if shard_seq else None
    if cfg.family in (DENSE, MOE, VLM):
        a = {"k": (None, "batch", seq, "kv_act"),
             "v": (None, "batch", seq, "kv_act")}
    elif cfg.family == HYBRID:
        a = {"k": (None, "batch", seq, "kv_act"),
             "v": (None, "batch", seq, "kv_act"),
             "conv": (None, None, "batch", None, "inner_act"),
             "ssm": (None, None, "batch", "inner_act", None)}
    elif cfg.family == SSM:
        a = {"tshift": (None, "batch", "embed_act"),
             "cshift": (None, "batch", "embed_act"),
             "wkv": (None, "batch", "heads_act", None, None)}
    elif cfg.family == ENCDEC:
        a = {"k": (None, "batch", seq, "kv_act"),
             "v": (None, "batch", seq, "kv_act"),
             "xk": (None, "batch", seq, "kv_act"),
             "xv": (None, "batch", seq, "kv_act")}
    else:
        raise ValueError(cfg.family)
    a["index"] = ("batch",)
    return a


def prefill(cfg: ModelConfig, p: Params, batch: Batch, max_len: int,
            ) -> Tuple[jax.Array, Params]:
    """Run the full prompt; returns (last-position logits, filled cache)."""
    positions = batch["positions"]

    if cfg.family in (DENSE, MOE, VLM):
        h = _embed_inputs(cfg, p, batch)

        def body(carry, lp):
            hh = carry
            hh = shard(hh, "batch", None, "embed_act")
            hh, kv, _ = B.block_prefill(cfg, lp, hh, positions)
            return hh, kv

        body = _maybe_remat(cfg, body)
        h, kvs = jax.lax.scan(body, h, p["blocks"])
        cache = _embed_cache(cfg, kvs, h.shape[0], max_len)
    elif cfg.family == HYBRID:
        h = _embed_inputs(cfg, p, batch)

        def body(carry, lp):
            hh = carry
            hh, kv, _ = HY.superblock_prefill(cfg, lp, hh, positions)
            return hh, kv

        h, kvs = jax.lax.scan(body, h, p["blocks"])
        cache = _embed_cache(cfg, {"k": kvs["k"], "v": kvs["v"]},
                             h.shape[0], max_len)
        cache["conv"] = kvs["conv"].astype(cfg.dtype)
        cache["ssm"] = kvs["ssm"]
    elif cfg.family == SSM:
        h = _embed_inputs(cfg, p, batch)

        def body(carry, lp):
            hh = carry
            xn = L.rmsnorm(hh, lp["ln1"], cfg.rms_eps)
            xprev = jnp.pad(xn, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
            tm, st = RW.rwkv_time_mix(cfg, lp, xn, xprev, return_state=True)
            hh = hh + tm
            xn2 = L.rmsnorm(hh, lp["ln2"], cfg.rms_eps)
            xprev2 = jnp.pad(xn2, ((0, 0), (1, 0), (0, 0)))[:, :-1, :]
            hh = hh + RW.rwkv_channel_mix(cfg, lp, xn2, xprev2)
            ent = {"tshift": xn[:, -1, :], "cshift": xn2[:, -1, :],
                   "wkv": st}
            return hh, ent

        h, kvs = jax.lax.scan(body, h, p["blocks"])
        cache = dict(kvs)
    elif cfg.family == ENCDEC:
        enc_h, enc_positions = _encode(cfg, p, batch)
        h = L.embed_tokens(cfg, p["embed"], batch["tokens"])

        def body(carry, lp):
            hh = carry
            hh, kv = ED.dec_block_prefill(cfg, lp, hh, positions, enc_h,
                                          enc_positions)
            return hh, kv

        h, kvs = jax.lax.scan(body, h, p["dec_blocks"])
        cache = _embed_cache(cfg, {"k": kvs["k"], "v": kvs["v"]},
                             h.shape[0], max_len)
        cache["xk"] = kvs["xk"]
        cache["xv"] = kvs["xv"]
    else:
        raise ValueError(cfg.family)

    prefilled = batch["tokens"].shape[1]
    if (cfg.frontend_embed_dim and "frontend" in batch
            and cfg.family != ENCDEC):
        prefilled += batch["frontend"].shape[1]
    Bsz = batch["tokens"].shape[0]
    cache["index"] = jnp.full((Bsz,), prefilled, jnp.int32)
    logits = _logits(cfg, p, h[:, -1:, :])
    return logits, cache


def _embed_cache(cfg: ModelConfig, kvs: Dict[str, jax.Array], batch: int,
                 max_len: int) -> Params:
    """Pad prefill K/V [L,B,S,kv] into a [L,B,max_len,kv] decode cache."""
    out = {}
    for name in ("k", "v"):
        t = kvs[name].astype(cfg.dtype)
        S = t.shape[2]
        pad = max_len - S
        out[name] = jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return out


def decode_step(cfg: ModelConfig, p: Params, tokens: jax.Array,
                cache: Params) -> Tuple[jax.Array, Params]:
    """One-token decode.  tokens: [B,1] -> (logits [B,1,V], new cache)."""
    index = cache["index"]
    h = L.embed_tokens(cfg, p["embed"], tokens)
    h = shard(h, "batch", None, "embed_act")
    new_cache = dict(cache)

    if cfg.family in (DENSE, MOE, VLM):
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(index[None, :, None],
                                   (3, tokens.shape[0], 1)).astype(jnp.int32)
        else:
            pos = index[:, None]

        def body(carry, xs):
            hh = carry
            lp, ck, cv = xs
            hh = shard(hh, "batch", None, "embed_act")
            hh, ck, cv = B.block_decode(cfg, lp, hh, pos, ck, cv, index)
            return hh, (ck, cv)

        h, (ks, vs) = jax.lax.scan(body, h, (p["blocks"], cache["k"],
                                             cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs
    elif cfg.family == HYBRID:
        pos = index[:, None]

        def body(carry, xs):
            hh = carry
            lp, ce = xs
            hh, ce = HY.superblock_decode(cfg, lp, hh, pos, ce, index)
            return hh, ce

        sub = {k: cache[k] for k in ("k", "v", "conv", "ssm")}
        h, sub = jax.lax.scan(body, h, (p["blocks"], sub))
        new_cache.update(sub)
    elif cfg.family == SSM:

        def body(carry, xs):
            hh = carry
            lp, ce = xs
            xn = L.rmsnorm(hh, lp["ln1"], cfg.rms_eps)
            tm, st = RW.rwkv_decode_time(cfg, lp, xn, ce)
            hh = hh + tm
            xn2 = L.rmsnorm(hh, lp["ln2"], cfg.rms_eps)
            cm, cshift = RW.rwkv_decode_channel(cfg, lp, xn2, ce["cshift"])
            hh = hh + cm
            st["cshift"] = cshift
            return hh, st

        sub = {k: cache[k] for k in ("tshift", "cshift", "wkv")}
        h, sub = jax.lax.scan(body, h, (p["blocks"], sub))
        new_cache.update(sub)
    elif cfg.family == ENCDEC:
        pos = index[:, None]

        def body(carry, xs):
            hh = carry
            lp, ce = xs
            hh, ce = ED.dec_block_decode(cfg, lp, hh, pos, ce, index)
            return hh, ce

        sub = {k: cache[k] for k in ("k", "v", "xk", "xv")}
        h, sub = jax.lax.scan(body, h, (p["dec_blocks"], sub))
        new_cache.update(sub)
    else:
        raise ValueError(cfg.family)

    new_cache["index"] = index + 1
    return _logits(cfg, p, h), new_cache
