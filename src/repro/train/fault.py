"""Fault tolerance harness: heartbeats, failure detection, straggler policy.

Single-process simulation of the multi-host control plane: workers (pods)
report heartbeats against a virtual clock; the monitor classifies them as
healthy / straggling / dead and the training loop reacts:

  * dead worker      -> restart from the last published checkpoint
                        (possibly with a different worker count — elastic);
  * straggler        -> "disconnected DP": drop it from this step's gradient
                        sync (bounded staleness, like an XUFS disconnect),
                        reconcile when it catches back up.

Fault *injection* is a schedule of (step, worker, kind) events so tests are
deterministic.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

HEALTHY = "healthy"
STRAGGLER = "straggler"
DEAD = "dead"


@dataclass
class FaultEvent:
    step: int
    worker: int
    kind: str            # "crash" | "straggle" | "recover"
    duration: int = 1    # steps (for straggle)


@dataclass
class WorkerState:
    index: int
    status: str = HEALTHY
    last_heartbeat: float = 0.0
    missed_syncs: int = 0
    straggle_until: int = -1


@dataclass
class FaultMonitor:
    n_workers: int
    heartbeat_timeout: float = 10.0
    max_staleness: int = 3          # straggler steps before forced restart
    schedule: List[FaultEvent] = field(default_factory=list)
    workers: Dict[int, WorkerState] = field(default_factory=dict)
    restarts: int = 0
    dropped_syncs: int = 0

    def __post_init__(self) -> None:
        for i in range(self.n_workers):
            self.workers[i] = WorkerState(index=i)

    # ---- injection ------------------------------------------------------
    def inject(self, step: int) -> List[FaultEvent]:
        """Fire scheduled events for ``step``.  Events are ONE-SHOT: a
        restart rewinds the step counter past the event, and refiring it
        would crash-loop forever."""
        fired = [e for e in self.schedule if e.step == step]
        self.schedule = [e for e in self.schedule if e.step != step]
        for e in fired:
            w = self.workers[e.worker]
            if e.kind == "crash":
                w.status = DEAD
            elif e.kind == "straggle":
                w.status = STRAGGLER
                w.straggle_until = step + e.duration
            elif e.kind == "recover":
                w.status = HEALTHY
                w.missed_syncs = 0
        return fired

    # ---- per-step protocol ----------------------------------------------
    def begin_step(self, step: int) -> Tuple[Set[int], bool]:
        """Returns (workers participating in this step's sync, must_restart)."""
        self.inject(step)
        participating: Set[int] = set()
        must_restart = False
        for w in self.workers.values():
            if w.status == DEAD:
                must_restart = True
                continue
            if w.status == STRAGGLER:
                if step >= w.straggle_until:
                    w.status = HEALTHY
                    w.missed_syncs = 0
                    participating.add(w.index)
                else:
                    w.missed_syncs += 1
                    self.dropped_syncs += 1
                    if w.missed_syncs > self.max_staleness:
                        must_restart = True   # too stale: re-mesh without it
                    continue
            else:
                participating.add(w.index)
        return participating, must_restart

    def replace_dead(self) -> int:
        """Elastic re-mesh: dead workers are replaced (or dropped)."""
        n = 0
        for w in self.workers.values():
            if w.status in (DEAD, STRAGGLER):
                w.status = HEALTHY
                w.missed_syncs = 0
                n += 1
        self.restarts += 1
        return n
