"""Trainer: the end-to-end loop wiring every substrate together.

Per step: data batch (XUFS-cached shards) -> jitted train_step ->
write-behind checkpoint pump (the WAL drains toward home on the virtual
WAN while compute proceeds) -> callback pump (invalidations) -> fault
monitor protocol (heartbeats / stragglers / restarts).

Crash recovery = exactly the paper's story: restart, ``client.sync()``
replays the meta-op queue, restore from the newest *complete* manifest.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import CheckpointManager
from repro.config.base import RunConfig
from repro.data.pipeline import DataPipeline
from repro.models import init_params
from repro.train.fault import FaultMonitor
from repro.train.step import make_train_step, make_opt_state


@dataclass
class TrainResult:
    steps_run: int
    restarts: int
    final_loss: float
    losses: List[float] = field(default_factory=list)
    checkpoints: List[int] = field(default_factory=list)


class Trainer:
    def __init__(self, run: RunConfig, pipeline: DataPipeline,
                 ckpt: CheckpointManager, *,
                 monitor: Optional[FaultMonitor] = None,
                 ckpt_every: int = 10, pump_ops_per_step: int = 2):
        self.run = run
        self.pipeline = pipeline
        self.ckpt = ckpt
        self.monitor = monitor or FaultMonitor(n_workers=1)
        self.ckpt_every = ckpt_every
        self.pump_ops_per_step = pump_ops_per_step
        self.step_fn = jax.jit(make_train_step(run))
        self.params: Any = None
        self.opt_state: Any = None
        self.step = 0

    # ---- state ------------------------------------------------------------
    def initialize(self) -> None:
        key = jax.random.PRNGKey(self.run.seed)
        self.params = init_params(self.run.model, key)
        self.opt_state = make_opt_state(self.run, self.params)
        self.step = 0

    def _state_tree(self) -> Dict[str, Any]:
        return {"params": self.params, "opt": self.opt_state}

    def save_checkpoint(self) -> None:
        self.ckpt.save(self.step, self._state_tree(),
                       extra={"data": self.pipeline.state()})

    def restore_latest(self) -> bool:
        """Post-crash: replay the WAL, then restore the newest manifest."""
        self.ckpt.client.sync()
        try:
            tree, manifest = self.ckpt.restore(self._state_tree())
        except FileNotFoundError:
            return False
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = int(manifest["step"])
        if "data" in manifest.get("extra", {}):
            self.pipeline.restore(manifest["extra"]["data"])
        return True

    # ---- loop ------------------------------------------------------------
    def train(self, num_steps: int) -> TrainResult:
        if self.params is None:
            self.initialize()
        losses: List[float] = []
        saved: List[int] = []
        target = self.step + num_steps
        while self.step < target:
            participating, must_restart = self.monitor.begin_step(self.step)
            if must_restart:
                # node failure: elastic re-mesh + restore from checkpoint
                self.monitor.replace_dead()
                restored = self.restore_latest()
                if not restored:
                    self.initialize()
                continue
            batch = self.pipeline.next_batch()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch)
            losses.append(float(metrics["loss"]))
            self.step += 1
            # write-behind: drain a few WAL ops toward home per step
            self.ckpt.client.pump(max_ops=self.pump_ops_per_step)
            self.ckpt.client.pump_callbacks()
            if self.step % self.ckpt_every == 0:
                self.save_checkpoint()
                saved.append(self.step)
        return TrainResult(steps_run=num_steps,
                           restarts=self.monitor.restarts,
                           final_loss=losses[-1] if losses else float("nan"),
                           losses=losses, checkpoints=saved)
