from repro.train.step import (  # noqa: F401
    make_train_step, make_eval_step, make_opt_state,
)
from repro.train.loop import Trainer, TrainResult  # noqa: F401
from repro.train.fault import FaultMonitor, FaultEvent  # noqa: F401
