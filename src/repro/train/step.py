"""train_step / eval_step builders: pure functions ready for jit/pjit.

``make_train_step`` returns ``step(params, opt_state, batch) -> (params,
opt_state, metrics)`` with microbatch gradient accumulation (lax.scan) and
the configured optimizer.  Sharding is injected via the active
ShardingCtx (parallel/context.py) + in/out shardings computed by the
caller (launch/dryrun.py, train/loop.py).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import RunConfig
from repro.models import loss_fn
from repro.optim import (
    adamw_update, clip_by_global_norm, lr_at, init_state,
    init_error, compress_decompress,
)

Params = Any
Batch = Dict[str, jax.Array]


def make_opt_state(run: RunConfig, params: Params) -> Dict[str, Any]:
    state = init_state(params, run.optim)
    if run.optim.grad_compress == "int8":
        state["ef_error"] = init_error(params)
    return state


def _split_microbatches(batch: Batch, n: int) -> Batch:
    """[B, ...] -> [n, B/n, ...] (positions for VLM split on dim 1)."""
    def split(name, x):
        if name == "positions" and x.ndim == 3 and x.shape[0] == 3:
            return jnp.moveaxis(
                x.reshape(3, n, x.shape[1] // n, *x.shape[2:]), 1, 0)
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])

    return {k: split(k, v) for k, v in batch.items()}


def make_train_step(run: RunConfig) -> Callable:
    cfg = run.model
    n_micro = run.microbatches

    def train_step(params: Params, opt_state: Dict[str, Any], batch: Batch,
                   ) -> Tuple[Params, Dict[str, Any], Dict[str, jax.Array]]:
        def lossm(p, b):
            return loss_fn(cfg, p, b)

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                lossm, has_aux=True)(params, batch)
        else:
            micro = _split_microbatches(batch, n_micro)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(lossm, has_aux=True)(
                    params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), ms = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss / n_micro
            metrics = jax.tree.map(lambda x: x[-1], ms)

        grads, gnorm = clip_by_global_norm(grads, run.optim.grad_clip)
        new_ef = None
        if run.optim.grad_compress == "int8":
            grads, new_ef = compress_decompress(grads,
                                                opt_state["ef_error"])
        lr = lr_at(opt_state["count"], run.optim)
        core_state = {k: opt_state[k] for k in ("m", "v", "count")}
        new_params, new_state = adamw_update(grads, core_state, params, lr,
                                             run.optim)
        if new_ef is not None:
            new_state["ef_error"] = new_ef
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        for k in ("ce", "aux", "z"):
            if k in metrics:
                out_metrics[k] = metrics[k]
        return new_params, new_state, out_metrics

    return train_step


def make_eval_step(run: RunConfig) -> Callable:
    cfg = run.model

    def eval_step(params: Params, batch: Batch) -> Dict[str, jax.Array]:
        loss, metrics = loss_fn(cfg, params, batch)
        return metrics

    return eval_step
