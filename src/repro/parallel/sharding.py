"""Logical-axis -> mesh-axis sharding policies.

Two policies, mirroring the paper mapping (DESIGN.md §5):

* ``baseline``  — plain DP x TP: parameters TP-sharded on ``model`` only,
  replicated across ``data`` (and ``pod``); the "remote-everything"
  reference point.
* ``fsdp``      — the XUFS-adapted *cached* layout: parameters stay
  replicated across pods (each pod holds a whole cached copy) but are
  ZeRO-3 sharded on ``data`` *within* the pod along their d_model
  ("embed") dimension, with TP on ``model``.  The layer scan then wraps
  per-layer all-gather / reduce-scatter — the collective-layer analogue
  of XUFS's striped, overlappable transfers.

Weight tensors use FLATTENED head dims ("heads"/"kv"), which divide the
16-way model axis for every assigned arch (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config.base import ShardingConfig
from repro.parallel.context import ShardingCtx

# Logical axes that carry tensor-parallel shards.
_TP_AXES = ("vocab", "heads", "kv", "mlp", "experts", "inner", "embed2",
            "vocab_act", "heads_act", "kv_act", "inner_act", "mlp_act")


def make_rules(cfg: ShardingConfig, *, multi_pod: bool,
               decode: bool = False) -> Dict[str, Any]:
    """Build the logical->mesh mapping for one (policy, topology, cell)."""
    tp = cfg.tp_axis
    batch_axes = (cfg.pod_axis, cfg.fsdp_axis) if multi_pod else cfg.fsdp_axis
    rules: Dict[str, Any] = {
        # ---- parameters -------------------------------------------------
        "vocab": tp,
        "heads": tp,
        "kv": tp,
        "mlp": tp,
        "expert_mlp": None,
        "experts": tp,          # EP: experts across the model axis
        "inner": tp,            # mamba d_inner
        "embed2": tp,           # rwkv channel-mix receptance out dim
        "embed": None,
        "layers": None,
        "sublayer": None,
        # ---- activations ---------------------------------------------------
        "batch": batch_axes,
        "embed_act": None,
        "vocab_act": tp,
        "heads_act": tp,
        "kv_act": tp,
        "inner_act": tp,
        "experts_act": tp,   # EP-sharded dispatch buffers
        "heads_dim": tp,     # expanded attention heads (post repeat_kv)
        "kv_seq": None,
    }
    if cfg.policy == "fsdp":
        # ZeRO-3 within the pod: shard the d_model dim of weights on data
        rules["embed"] = cfg.fsdp_axis
    if cfg.shard_seq and decode:
        # long-context decode (batch too small to shard): SP on the cache
        rules["batch"] = None
        rules["kv_seq"] = cfg.fsdp_axis
    return rules


def make_ctx(mesh: Mesh, cfg: ShardingConfig, *, decode: bool = False,
             ) -> ShardingCtx:
    multi_pod = "pod" in mesh.axis_names
    return ShardingCtx(mesh=mesh,
                       rules=make_rules(cfg, multi_pod=multi_pod,
                                        decode=decode))


def tree_shardings(ctx: ShardingCtx, axes_tree: Any):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree.map(
        lambda ax: ctx.sharding(ax),
        axes_tree, is_leaf=lambda x: isinstance(x, tuple))


def batch_shardings(ctx: ShardingCtx, batch_tree: Any):
    """Shardings for an input batch: leading batch dim sharded.

    VLM positions are [3, B, S] (batch on dim 1); everything else [B, ...].
    """
    out = {}
    for name, leaf in batch_tree.items():
        if name == "positions" and leaf.ndim == 3:
            # VLM M-RoPE positions are [3, B, S]: batch on dim 1
            out[name] = ctx.sharding((None, "batch", None))
        else:
            out[name] = ctx.sharding(("batch",) + (None,) * (leaf.ndim - 1))
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def sanitize_shardings(shardings, shapes):
    """Drop mesh axes from dims they don't divide (explicit pjit
    in_shardings require divisibility; propagation would pad instead).

    E.g. seamless's vocab 256206 on a 16-way model axis, or RWKV6's 40
    heads: those dims fall back to replication, everything else keeps its
    sharding.  Both trees must be isomorphic; ``shapes`` leaves need
    ``.shape``.
    """
    def fix(sh, spec_leaf):
        if not isinstance(sh, NamedSharding):
            return sh
        shape = spec_leaf.shape
        parts = list(sh.spec) + [None] * (len(shape) - len(sh.spec))
        changed = False
        for i, axes in enumerate(parts):
            n = _axis_size(sh.mesh, axes)
            if n > 1 and shape[i] % n != 0:
                parts[i] = None
                changed = True
        if not changed:
            return sh
        return NamedSharding(sh.mesh, P(*parts))

    return jax.tree.map(fix, shardings, shapes,
                        is_leaf=lambda x: isinstance(x, NamedSharding))
