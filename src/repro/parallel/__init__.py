from repro.parallel.context import (  # noqa: F401
    ShardingCtx, sharding_ctx, current_ctx, shard,
)
