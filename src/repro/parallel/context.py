"""Active-mesh sharding context.

Models annotate activations with *logical* axis names; when a
:class:`ShardingCtx` is active those names resolve to mesh axes and a
``with_sharding_constraint`` is applied, otherwise the call is a no-op —
so the same model code runs single-device (tests) and multi-pod (dry-run).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

_STATE = threading.local()


@dataclass
class ShardingCtx:
    mesh: Mesh
    # logical activation/param axis name -> mesh axis (or tuple of axes)
    rules: Dict[str, MeshAxes] = field(default_factory=dict)

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        out = []
        for name in logical:
            out.append(None if name is None else self.rules.get(name))
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical))


def current_ctx() -> Optional[ShardingCtx]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def sharding_ctx(ctx: Optional[ShardingCtx]):
    prev = current_ctx()
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the logical spec if a mesh context is active."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"rank mismatch: {logical} for shape {x.shape}")
    return jax.lax.with_sharding_constraint(x, ctx.sharding(logical))
