"""Explicit collectives for shard_map regions.

``hierarchical_psum``: reduce-scatter on the fast intra-pod axis, psum on
the slow cross-pod axis over the scattered shard, then all-gather — the
cross-pod link carries 1/|data| of the bytes a flat psum would ship, which
is the collective-layer reading of XUFS's cache-local/WAN-async split.

``compressed_psum``: int8-quantized cross-axis psum (pairs with the error
feedback in optim/compress.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis: str) -> int:
    """``lax.axis_size`` only exists on newer jax; a psum of ones is the
    portable spelling (constant-folded, never hits the wire)."""
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis)
    return lax.psum(1, axis)


def hierarchical_psum(x: jax.Array, pod_axis: str, inner_axis: str,
                      ) -> jax.Array:
    """psum over (pod_axis, inner_axis) with pod traffic minimized.

    Requires x's leading dim divisible by the inner axis size.
    """
    n_inner = _axis_size(inner_axis)
    lead = x.shape[0]
    if lead % n_inner != 0:
        # fall back: flat psum (correct, just not bandwidth-optimal)
        return lax.psum(x, (pod_axis, inner_axis))
    # reduce-scatter within pod: each inner rank owns a 1/n_inner slice
    shard = lax.psum_scatter(x, inner_axis, scatter_dimension=0,
                             tiled=True)
    # cross-pod reduce touches only the owned slice
    shard = lax.psum(shard, pod_axis)
    # all-gather the slices back within the pod
    return lax.all_gather(shard, inner_axis, axis=0, tiled=True)


def compressed_psum(x: jax.Array, axis: str, *, dequant_dtype=jnp.float32,
                    ) -> jax.Array:
    """Quantize-locally-then-reduce psum across ``axis``.

    Each rank quantizes its contribution to int8 (per-tensor scale) before
    the reduction; the reduction itself sums the *dequantized* values so
    the result is exact given the quantized contributions.  On-wire int8
    (uniform-scale) is a transport detail the simulation abstracts; the
    quantization error this op introduces is what optim/compress.py's
    error feedback re-injects.
    """
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.where(scale == 0.0, 1.0, scale)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    contrib = q.astype(dequant_dtype) * scale
    return lax.psum(contrib, axis)
