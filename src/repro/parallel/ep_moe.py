"""Expert-parallel MoE with explicit all-to-all dispatch (shard_map).

The jit/GSPMD MoE (models/moe.py) lets the partitioner choose the dispatch
collectives; on the MoE train cells that choice is all-reduce-heavy
(EXPERIMENTS.md §Perf).  This module is the production EP form: devices
along the ``model`` axis own ``E / n_tp`` experts each; every device packs
a fixed-capacity per-destination buffer, one ``lax.all_to_all`` ships
tokens to their expert owners, local experts run, and a second all-to-all
ships results back.  Wire bytes are exactly 2 x cap x d per device pair —
no reductions.

Differentiable (all_to_all transposes to all_to_all), validated against
the GSPMD path in tests/test_ep_moe.py on an 8-device host mesh.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.config.base import ModelConfig


def _local_pack(cfg: ModelConfig, router_logits, xf, n_shards: int,
                cap: int):
    """Per-device: route local tokens, pack per-destination buffers.

    xf: [T_loc, d].  Returns (buffers [n_shards, cap, d],
    meta ids [n_shards, cap, 2] = (local expert idx on dst, src row),
    combine weights [T_loc, k], dst/slot per assignment).
    """
    m = cfg.moe
    E, k = m.num_experts, m.experts_per_token
    e_loc = E // n_shards
    T = xf.shape[0]
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_w, ids = lax.top_k(probs, k)                    # [T, k]
    gate_w = gate_w / jnp.sum(gate_w, axis=-1, keepdims=True)

    flat_ids = ids.reshape(T * k)
    dst = flat_ids // e_loc                              # owner shard
    # slot within the destination buffer: running count per dst
    oh = jax.nn.one_hot(dst, n_shards, dtype=jnp.int32)  # [T*k, S]
    slot = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(T * k), dst]
    keep = slot < cap
    slot_c = jnp.where(keep, slot, cap)                  # park drops

    buffers = jnp.zeros((n_shards, cap + 1, xf.shape[1]), xf.dtype)
    srcs = jnp.repeat(jnp.arange(T), k)
    buffers = buffers.at[dst, slot_c].set(xf[srcs], mode="drop")
    # metadata rides a separate (small) all_to_all: local expert + src row
    meta = jnp.full((n_shards, cap + 1, 2), -1, jnp.int32)
    meta = meta.at[dst, slot_c, 0].set(flat_ids % e_loc, mode="drop")
    meta = meta.at[dst, slot_c, 1].set(srcs, mode="drop")
    return buffers, meta, gate_w, dst, slot_c, keep


def _expert_ffn(p_loc: Dict[str, Any], xe: jax.Array, eid: jax.Array,
                dt) -> jax.Array:
    """Apply each received token's expert.  xe: [R, d]; eid: [R] local ids."""
    # gather each token's expert weights: fine for e_loc small (EP sliced)
    wg = p_loc["wi_gate"][eid]                          # [R, d, f]
    wu = p_loc["wi_up"][eid]
    wo = p_loc["wo"][eid]
    gate = jnp.einsum("rd,rdf->rf", xe, wg.astype(dt))
    up = jnp.einsum("rd,rdf->rf", xe, wu.astype(dt))
    return jnp.einsum("rf,rfd->rd", jax.nn.silu(gate) * up, wo.astype(dt))


def ep_moe_apply(cfg: ModelConfig, params: Dict[str, Any], x: jax.Array,
                 mesh: Mesh, *, tp_axis: str = "model",
                 batch_axes=("data",), capacity_factor: float = None,
                 ) -> jax.Array:
    """Drop-in EP forward for a [B,S,d] activation on ``mesh``.

    params: {"router" [d,E], "wi_gate"/"wi_up" [E,d,f], "wo" [E,f,d]} —
    expert tensors sharded on their leading dim over ``tp_axis``.
    """
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    n_tp = mesh.shape[tp_axis]
    n_batch = 1
    for a in batch_axes:
        n_batch *= mesh.shape[a]
    B, S, d = x.shape
    T_loc = (B // n_batch) * S
    cf = capacity_factor or m.capacity_factor
    cap = max(int(cf * T_loc * m.experts_per_token / n_tp),
              m.experts_per_token)

    def local(x_loc, router, wg, wu, wo):
        p_loc = {"wi_gate": wg, "wi_up": wu, "wo": wo}
        xf = x_loc.reshape(-1, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        buffers, meta, gate_w, dst, slot_c, keep = _local_pack(
            cfg, logits, xf, n_tp, cap)
        # ship tokens to expert owners (and metadata alongside)
        recv = lax.all_to_all(buffers[:, :cap], tp_axis, 0, 0, tiled=False)
        recv_meta = lax.all_to_all(meta[:, :cap], tp_axis, 0, 0,
                                   tiled=False)
        R = n_tp * cap
        xe = recv.reshape(R, d)
        eid = jnp.maximum(recv_meta.reshape(R, 2)[:, 0], 0)
        valid = recv_meta.reshape(R, 2)[:, 0] >= 0
        ye = _expert_ffn(p_loc, xe, eid, dt)
        ye = jnp.where(valid[:, None], ye, 0.0).astype(dt)
        # ship results back
        back = lax.all_to_all(ye.reshape(n_tp, cap, d), tp_axis, 0, 0,
                              tiled=False)
        # unpack: assignment j of token t sits at (dst[tk], slot[tk])
        Tk = xf.shape[0] * m.experts_per_token
        contrib = back[dst, jnp.minimum(slot_c, cap - 1)]      # [T*k, d]
        contrib = jnp.where(keep[:, None], contrib, 0.0)
        w_flat = gate_w.reshape(Tk)[:, None].astype(dt)
        out = jnp.zeros_like(xf)
        out = out.at[jnp.repeat(jnp.arange(xf.shape[0]),
                                m.experts_per_token)].add(contrib * w_flat)
        return out.reshape(x_loc.shape)

    pspec_x = P(batch_axes, None, None)
    out = shard_map(
        local, mesh=mesh,
        in_specs=(pspec_x, P(None, None), P(tp_axis, None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None)),
        out_specs=pspec_x,
        check_rep=False,
    )(x, params["router"], params["wi_gate"], params["wi_up"],
      params["wo"])
    return out
