from repro.config.base import (  # noqa: F401
    DENSE, MOE, HYBRID, SSM, ENCDEC, VLM, FAMILIES,
    TRAIN, PREFILL, DECODE, SHAPES,
    MambaConfig, RwkvConfig, MoeConfig, ModelConfig, ShapeConfig,
    MeshConfig, OptimConfig, ShardingConfig, RunConfig,
    reduce_config,
)
