"""Configuration dataclasses for XUFS-JAX.

Every model family (dense / moe / hybrid / ssm / encdec / vlm) is described
by a single frozen :class:`ModelConfig`; shape cells by :class:`ShapeConfig`;
the distributed runtime by :class:`MeshConfig` / :class:`RunConfig`.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Tuple

# ---------------------------------------------------------------------------
# Model families
# ---------------------------------------------------------------------------

DENSE = "dense"
MOE = "moe"
HYBRID = "hybrid"  # interleaved SSM + attention (Jamba)
SSM = "ssm"        # attention-free (RWKV6)
ENCDEC = "encdec"  # encoder-decoder (Seamless-M4T backbone)
VLM = "vlm"        # vision-language backbone (M-RoPE)

FAMILIES = (DENSE, MOE, HYBRID, SSM, ENCDEC, VLM)


@dataclass(frozen=True)
class MambaConfig:
    """Mamba(-1) selective-SSM block hyperparameters (Jamba defaults)."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank if self.dt_rank > 0 else -(-d_model // 16)


@dataclass(frozen=True)
class RwkvConfig:
    """RWKV6 (Finch) block hyperparameters."""

    head_dim: int = 64
    decay_lora: int = 64     # rank of the data-dependent decay LoRA
    mix_lora: int = 32       # rank of the token-shift mix LoRA
    gate_lora: int = 64


@dataclass(frozen=True)
class MoeConfig:
    num_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    # apply MoE on layers where (layer_idx % moe_every) == moe_offset
    moe_every: int = 1
    moe_offset: int = 0
    router_jitter: float = 0.0
    capacity_factor: float = 1.25
    # dense d_ff used on non-MoE layers of a partially-MoE model (0 = none)
    d_ff_shared: int = 0
    # token chunking: bounds the [E, C, d] dispatch buffers for 1M-token
    # batches (32k prefill) to a fixed working set (0 = no chunking)
    chunk_tokens: int = 65536


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 1_000_000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False

    # optional sub-configs
    moe: Optional[MoeConfig] = None
    mamba: Optional[MambaConfig] = None
    rwkv: Optional[RwkvConfig] = None

    # hybrid (Jamba): block period and which position inside it is attention
    hybrid_period: int = 0
    hybrid_attn_pos: int = 0

    # enc-dec
    encoder_layers: int = 0
    decoder_layers: int = 0

    # vlm: M-RoPE sections over head_dim/2 (temporal, height, width)
    mrope_sections: Tuple[int, ...] = ()

    # modality frontend stub: dims of the precomputed embedding inputs
    frontend_embed_dim: int = 0   # 0 -> token ids only

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"

    # implementation switches
    attention_impl: str = "xla"   # "xla" | "pallas"
    scan_impl: str = "xla"        # ssm/rwkv scan: "xla" | "pallas"
    remat: str = "full"           # "none" | "dots" | "full"
    # layers applied per scan step: the carry stash shrinks by this factor
    # (recompute grows by the same); must divide num_layers
    layers_per_step: int = 1

    # ---- derived -----------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.moe is not None and self.moe.num_experts > 0

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM state or hybrid)."""
        return self.family in (SSM, HYBRID)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Parameter count (embedding + blocks), used for roofline MODEL_FLOPS.
    def param_count(self, active_only: bool = False) -> int:
        d = self.d_model
        embed = self.vocab_size * d
        unembed = 0 if self.tie_embeddings else self.vocab_size * d
        frontend = self.frontend_embed_dim * d if self.frontend_embed_dim else 0

        def attn_params() -> int:
            p = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.qkv_bias:
                p += self.q_dim + 2 * self.kv_dim
            if self.qk_norm:
                p += 2 * self.head_dim
            return p + 2 * d  # two RMSNorm scales

        def mlp_params(dff: int) -> int:
            return 3 * d * dff  # SwiGLU: gate, up, down

        def moe_params(active: bool) -> int:
            assert self.moe is not None
            n = self.moe.experts_per_token if active else self.moe.num_experts
            p = n * 3 * d * self.moe.d_ff_expert
            p += d * self.moe.num_experts  # router
            if self.moe.d_ff_shared:
                p += mlp_params(self.moe.d_ff_shared)
            return p

        def mamba_params() -> int:
            assert self.mamba is not None
            di = self.mamba.expand * d
            r = self.mamba.resolved_dt_rank(d)
            p = d * 2 * di                      # in_proj (x, z)
            p += di * self.mamba.d_conv + di    # conv1d + bias
            p += di * (r + 2 * self.mamba.d_state)  # x_proj
            p += r * di + di                    # dt_proj
            p += di * self.mamba.d_state + di   # A_log, D
            p += di * d                         # out_proj
            return p + 2 * d

        def rwkv_params() -> int:
            assert self.rwkv is not None
            c = self.rwkv
            p = 4 * d * d + d * d               # r,k,v,g + output
            p += 5 * (d * c.mix_lora + c.mix_lora * d) + 5 * d  # ddlerp
            p += d * c.decay_lora + c.decay_lora * d + d        # decay lora
            p += d + d                          # time_first (u), ln_x
            p += 2 * d * self.d_ff + self.d_ff * d              # channel mix
            return p + 2 * d

        if self.family in (DENSE, VLM):
            block = attn_params() + mlp_params(self.d_ff)
            total = self.num_layers * block
        elif self.family == MOE:
            block = attn_params() + moe_params(active_only)
            total = self.num_layers * block
        elif self.family == HYBRID:
            assert self.hybrid_period > 0
            n_attn = self.num_layers // self.hybrid_period
            n_mamba = self.num_layers - n_attn
            n_moe = self.num_layers // max(self.moe.moe_every, 1) if self.is_moe else 0
            n_mlp = self.num_layers - n_moe
            total = n_attn * attn_params() + n_mamba * mamba_params()
            total += n_moe * (moe_params(active_only) if self.is_moe else 0)
            total += n_mlp * mlp_params(self.d_ff)
        elif self.family == SSM:
            total = self.num_layers * rwkv_params()
        elif self.family == ENCDEC:
            enc = self.encoder_layers * (attn_params() + mlp_params(self.d_ff))
            # decoder adds cross-attention
            dec = self.decoder_layers * (2 * attn_params() + mlp_params(self.d_ff))
            total = enc + dec
        else:  # pragma: no cover
            raise ValueError(self.family)
        return total + embed + unembed + frontend


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

TRAIN = "train"
PREFILL = "prefill"
DECODE = "decode"


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str             # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == DECODE


# The four assigned LM shape cells.
SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", TRAIN, 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", PREFILL, 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", DECODE, 32_768, 128),
    "long_500k": ShapeConfig("long_500k", DECODE, 524_288, 1),
}


# ---------------------------------------------------------------------------
# Mesh / run configs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    """Production mesh description (see launch/mesh.py)."""

    data: int = 16
    model: int = 16
    pods: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods

    @property
    def multi_pod(self) -> bool:
        return self.pods > 1


@dataclass(frozen=True)
class OptimConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # "fp32" | "int8" blockwise-quantized first/second moments
    state_dtype: str = "fp32"
    int8_block: int = 256
    # cross-pod error-feedback gradient compression ("none" | "int8")
    grad_compress: str = "none"


@dataclass(frozen=True)
class ShardingConfig:
    """Logical->physical sharding policy knobs (parallel/sharding.py)."""

    policy: str = "fsdp"       # "baseline" (DP x TP) | "fsdp" (cached/ZeRO)
    shard_seq: bool = False    # SP: shard sequence/state on data axis (long ctx)
    fsdp_axis: str = "data"
    tp_axis: str = "model"
    pod_axis: str = "pod"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    optim: OptimConfig = OptimConfig()
    sharding: ShardingConfig = ShardingConfig()
    microbatches: int = 1
    seed: int = 0

    def replace(self, **kw: Any) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Reduction helper: full config -> smoke-test config
# ---------------------------------------------------------------------------

def reduce_config(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
                  heads: int = 4, kv_heads: int = 2, head_dim: int = 16,
                  d_ff: int = 128, vocab: int = 512) -> ModelConfig:
    """Shrink a full architecture config to a CPU-smoke-testable sibling.

    Keeps family, layer pattern (hybrid period, moe stride, enc/dec split)
    and feature flags identical; shrinks all widths.
    """
    kw: dict = dict(
        name=cfg.name + "-tiny",
        num_layers=layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=min(kv_heads, heads),
        head_dim=head_dim,
        d_ff=d_ff,
        vocab_size=vocab,
        remat="none",
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 8),
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            d_ff_expert=min(cfg.moe.d_ff_expert, 64) or 64,
            d_ff_shared=min(cfg.moe.d_ff_shared, d_ff) if cfg.moe.d_ff_shared else 0,
        )
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=8)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_dim=16, decay_lora=8, mix_lora=8, gate_lora=8)
        kw["num_heads"] = d_model // 16
        kw["num_kv_heads"] = d_model // 16
        kw["head_dim"] = 16
    if cfg.family == HYBRID:
        kw["num_layers"] = max(layers, cfg.hybrid_period)
        # keep one full hybrid period so the attn/mamba interleave is exercised
        kw["num_layers"] = cfg.hybrid_period
    if cfg.family == ENCDEC:
        kw["encoder_layers"] = layers
        kw["decoder_layers"] = layers
        kw["num_layers"] = 2 * layers
    if cfg.frontend_embed_dim:
        kw["frontend_embed_dim"] = d_model
    if cfg.mrope_sections:
        s = head_dim // 2
        kw["mrope_sections"] = (s - 2 * (s // 3), s // 3, s // 3)
    return cfg.replace(**kw)
