"""Qwen2-VL-72B — VLM backbone with M-RoPE (dynamic resolution frontend stub).

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064
mrope sections (t,h,w) = (16, 24, 24) over head_dim/2 = 64.
The vision frontend is a STUB: input_specs() provides precomputed patch
embeddings (already merged/projected to d_model) + 3D M-RoPE position ids.
[arXiv:2409.12191; hf]
"""
from repro.config import ModelConfig, VLM

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family=VLM,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    qk_norm=False,
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    frontend_embed_dim=8192,
)
