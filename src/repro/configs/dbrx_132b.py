"""DBRX-132B — 16-expert top-4 fine-grained MoE decoder.

40L d_model=6144 48H (GQA kv=8) d_ff(expert)=10752 vocab=100352, MoE 16e top-4
[hf:databricks/dbrx-base; unverified]
"""
from repro.config import ModelConfig, MoeConfig, MOE

CONFIG = ModelConfig(
    name="dbrx-132b",
    family=MOE,
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    qkv_bias=False,
    qk_norm=False,
    rope_theta=500_000.0,
    moe=MoeConfig(
        num_experts=16,
        experts_per_token=4,
        d_ff_expert=10752,
        moe_every=1,
        # wide experts: smaller token chunks keep [E,C,d_ff] ~1 GB
        chunk_tokens=8192,
    ),
)
