"""Jamba-1.5-Large-398B — hybrid Mamba+attention (1:7) with 16e top-2 MoE.

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536, MoE 16e top-2
Attention every 8th layer (1:7 attn:mamba interleave); MoE every 2nd layer.
[arXiv:2403.19887; hf]
"""
from repro.config import ModelConfig, MoeConfig, MambaConfig, HYBRID

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family=HYBRID,
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    qkv_bias=False,
    qk_norm=False,
    rope_theta=0.0,  # Jamba attention layers are NoPE
    moe=MoeConfig(
        num_experts=16,
        experts_per_token=2,
        d_ff_expert=24576,
        moe_every=2,       # MoE on odd layers within each period-8 block
        moe_offset=1,
        # very wide experts: bound the [E,C,d_ff] dispatch working set
        chunk_tokens=8192,
    ),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    hybrid_period=8,
    hybrid_attn_pos=4,     # 1 attention layer per 8 (positions 4, 12, ...)
)
