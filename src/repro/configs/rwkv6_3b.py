"""RWKV6-3B (Finch) — attention-free RNN with data-dependent decay.

32L d_model=2560 (40 heads x 64) d_ff=8960 vocab=65536
[arXiv:2404.05892; hf]
"""
from repro.config import ModelConfig, RwkvConfig, SSM

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family=SSM,
    num_layers=32,
    d_model=2560,
    num_heads=40,       # d_model / rwkv.head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    rwkv=RwkvConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
)
