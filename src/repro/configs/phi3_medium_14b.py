"""Phi-3-medium-14B — dense GQA decoder (RoPE, SwiGLU).

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
[arXiv:2404.14219; unverified]
"""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="phi3-medium-14b",
    family=DENSE,
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    qkv_bias=False,
    qk_norm=False,
    rope_theta=10_000.0,
)
