"""Architecture registry: ``--arch <id>`` ids map to exact published configs.

Each module defines ``CONFIG``; ``get_config(arch)`` resolves by id, and
``get_tiny_config(arch)`` returns the reduced smoke-test sibling.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig, ShapeConfig, SHAPES, reduce_config

_MODULES: Dict[str, str] = {
    "qwen2.5-32b": "qwen2_5_32b",
    "qwen3-4b": "qwen3_4b",
    "qwen3-8b": "qwen3_8b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "dbrx-132b": "dbrx_132b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "rwkv6-3b": "rwkv6_3b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_tiny_config(arch: str) -> ModelConfig:
    return reduce_config(get_config(arch))


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(arch: str) -> List[str]:
    """The shape cells that are *runnable* for this arch (assignment rules).

    - ``long_500k`` needs sub-quadratic attention: only SSM/hybrid archs.
    - all assigned archs have a decoder, so decode_32k runs everywhere.
    """
    cfg = get_config(arch)
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        names.append("long_500k")
    return names


def skipped_cells(arch: str) -> List[str]:
    return [s for s in SHAPES if s not in cells(arch)]
