"""Qwen3-MoE-30B-A3B — 128-expert top-8 MoE decoder with QK-norm.

48L d_model=2048 32H (GQA kv=4) d_ff(expert)=768 vocab=151936, MoE 128e top-8
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
from repro.config import ModelConfig, MoeConfig, MOE

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family=MOE,
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    qkv_bias=False,
    qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoeConfig(
        num_experts=128,
        experts_per_token=8,
        d_ff_expert=768,
        moe_every=1,
    ),
)
