"""SeamlessM4T-medium — encoder-decoder multimodal backbone (speech stub).

12L(enc) + 12L(dec) d_model=1024 16H (kv=16, i.e. MHA) d_ff=4096 vocab=256206
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings for the encoder; the text decoder consumes token ids.
[arXiv:2308.11596; hf]
"""
from repro.config import ModelConfig, ENCDEC

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family=ENCDEC,
    num_layers=24,
    encoder_layers=12,
    decoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    qkv_bias=True,
    qk_norm=False,
    rope_theta=10_000.0,
    frontend_embed_dim=1024,
)
