"""Qwen2.5-32B — dense GQA decoder with QKV bias.

64L d_model=5120 40H (GQA kv=8) d_ff=27648 vocab=152064
[hf:Qwen/Qwen2.5-0.5B family; hf]
"""
from repro.config import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family=DENSE,
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    qkv_bias=True,
    qk_norm=False,
    rope_theta=1_000_000.0,
)
