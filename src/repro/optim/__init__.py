from repro.optim.adamw import (  # noqa: F401
    init_state, state_axes, adamw_update, clip_by_global_norm, global_norm,
    q8_encode, q8_decode,
)
from repro.optim.schedule import lr_at  # noqa: F401
from repro.optim.compress import init_error, compress_decompress  # noqa: F401
