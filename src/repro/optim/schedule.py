"""LR schedules: linear warmup + cosine decay."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config.base import OptimConfig


def lr_at(step, cfg: OptimConfig):
    s = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    total = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    frac = jnp.clip((s - cfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    floor = 0.1
    return cfg.lr * warm * (floor + (1 - floor) * cos)
