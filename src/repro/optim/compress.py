"""Error-feedback int8 gradient compression (cross-pod sync trick).

The XUFS reading: cross-pod links are the "WAN"; gradients shipped across
them get compressed with residual error feedback so the quantization error
is re-injected next step instead of lost (convergence-preserving, cf.
1-bit SGD / EF-SGD lineage).

Under ``jit`` the compression is applied to the global gradient before the
optimizer; on a real multi-pod deployment the same codec wraps the
cross-pod all-reduce inside ``shard_map`` (see parallel/collectives.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.optim.adamw import q8_encode, q8_decode

Params = Any
BLOCK = 256


def init_error(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads: Params, error: Params,
                        ) -> Tuple[Params, Params]:
    """Returns (decompressed grads as seen post-allreduce, new error fb)."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        flat = g32.reshape(1, -1) if g32.ndim == 0 else g32
        q, s = q8_encode(flat, BLOCK)
        deq = q8_decode(q, s, BLOCK)
        if g32.ndim == 0:
            deq = deq.reshape(())
        new_e = g32 - deq
        return deq.astype(g.dtype), new_e

    out = jax.tree.map(one, grads, error)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, err
