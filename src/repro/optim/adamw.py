"""AdamW with optional blockwise-int8 moment quantization.

Pure-pytree implementation (no optax in this environment).  The int8 path
stores ``m``/``v`` as int8 codes plus per-block f32 scales along the last
dim — 398 B-param Jamba's optimizer state drops from 12 to ~2.3 bytes/param,
which is what lets the single-pod (256 x 16 GB) train cell fit
(EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import OptimConfig

Params = Any


# ---------------------------------------------------------------------------
# blockwise int8 codec
# ---------------------------------------------------------------------------

def _blocks(n: int, block: int) -> int:
    return -(-n // block)


def q8_encode(x: jax.Array, block: int) -> Tuple[jax.Array, jax.Array]:
    """x [..., D] -> (codes int8 [..., D], scales f32 [..., nb])."""
    D = x.shape[-1]
    nb = _blocks(D, block)
    pad = nb * block - D
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    xb = xp.reshape(*x.shape[:-1], nb, block)
    scale = jnp.max(jnp.abs(xb), axis=-1) / 127.0
    safe = jnp.where(scale == 0.0, 1.0, scale)
    codes = jnp.clip(jnp.round(xb / safe[..., None]), -127, 127
                     ).astype(jnp.int8)
    return codes.reshape(*x.shape[:-1], nb * block)[..., :D], scale


def q8_decode(codes: jax.Array, scale: jax.Array, block: int) -> jax.Array:
    D = codes.shape[-1]
    nb = scale.shape[-1]
    pad = nb * block - D
    cp = jnp.pad(codes, [(0, 0)] * (codes.ndim - 1) + [(0, pad)])
    xb = cp.reshape(*codes.shape[:-1], nb, block).astype(jnp.float32)
    out = xb * scale[..., None]
    return out.reshape(*codes.shape[:-1], nb * block)[..., :D]


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

def init_state(params: Params, cfg: OptimConfig) -> Dict[str, Any]:
    zeros_like_f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.state_dtype == "int8":
        def zq(p):
            return {
                "q": jnp.zeros(p.shape, jnp.int8),
                "s": jnp.zeros(p.shape[:-1]
                               + (_blocks(p.shape[-1] if p.ndim else 1,
                                          cfg.int8_block),), jnp.float32),
            }
        mk = lambda p: zq(p if p.ndim else p.reshape(1))
        m = jax.tree.map(mk, params)
        v = jax.tree.map(mk, params)
    else:
        m = jax.tree.map(zeros_like_f32, params)
        v = jax.tree.map(zeros_like_f32, params)
    return {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}


def state_axes(param_axes_tree: Any, cfg: OptimConfig) -> Dict[str, Any]:
    """Optimizer-state logical axes mirror the parameter axes."""
    is_ax = lambda x: isinstance(x, tuple)
    if cfg.state_dtype == "int8":
        def mk(ax):
            return {"q": ax, "s": ax[:-1] + (None,) if ax else (None,)}
        m = jax.tree.map(mk, param_axes_tree, is_leaf=is_ax)
        v = jax.tree.map(mk, param_axes_tree, is_leaf=is_ax)
    else:
        m = param_axes_tree
        v = param_axes_tree
    return {"m": m, "v": v, "count": ()}


# ---------------------------------------------------------------------------
# update
# ---------------------------------------------------------------------------

def global_norm(tree: Params) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Params, max_norm: float,
                        ) -> Tuple[Params, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def _decay_mask(path: Tuple, p: jax.Array) -> bool:
    """Weight decay on matrices only (skip norms/biases/scalars)."""
    return p.ndim >= 2


def adamw_update(grads: Params, state: Dict[str, Any], params: Params,
                 lr: jax.Array, cfg: OptimConfig) -> Tuple[Params, Dict]:
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf
    blk = cfg.int8_block
    use_q8 = cfg.state_dtype == "int8"

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        if use_q8:
            g2 = g32 if g32.ndim else g32.reshape(1)
            m_f = q8_decode(m["q"], m["s"], blk)
            # v codes live in the sqrt domain: a linear int8 grid on v
            # rounds small second moments to 0 and the step m/(sqrt(v)+eps)
            # explodes; quantizing sqrt(v) bounds the error of sqrt(v)
            # itself, keeping the int8 trajectory on the fp32 one.
            v_f = jnp.square(q8_decode(v["q"], v["s"], blk))
            m_new = cfg.b1 * m_f + (1 - cfg.b1) * g2
            v_new = cfg.b2 * v_f + (1 - cfg.b2) * jnp.square(g2)
        else:
            m_new = cfg.b1 * m + (1 - cfg.b1) * g32
            v_new = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mh = m_new / bc1
        vh = v_new / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if use_q8 and not g32.ndim:
            step = step.reshape(())
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        new_p = (p.astype(jnp.float32) - lr * (step + wd *
                                               p.astype(jnp.float32)))
        if use_q8:
            mq, ms = q8_encode(m_new, blk)
            vq, vs = q8_encode(jnp.sqrt(v_new), blk)
            return new_p.astype(p.dtype), {"q": mq, "s": ms}, \
                {"q": vq, "s": vs}
        return new_p.astype(p.dtype), m_new, v_new

    is_state_leaf = (lambda x: isinstance(x, dict) and set(x) == {"q", "s"}) \
        if use_q8 else None
    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"]) if use_q8 else \
        jax.tree.leaves(state["m"])
    flat_v = treedef.flatten_up_to(state["v"]) if use_q8 else \
        jax.tree.leaves(state["v"])
    flat_p = jax.tree.leaves(params)
    outs = [upd(g, m, v, p)
            for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, {"m": new_m, "v": new_v, "count": count}
