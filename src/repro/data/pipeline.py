"""Data pipeline: deterministic synthetic token shards served through XUFS.

Shards live as objects in the home store (the "input data" of the paper's
workflow §2.1, step 3); the pipeline reads them through the XufsClient so
they are whole-object cached, prefetched in parallel, and survive home
disconnects once cached — the trainer never stalls on the WAN.

Determinism: shard contents are a pure function of (seed, shard_index), so
an elastic re-shard or a restart resumes exactly.
"""
from __future__ import annotations

import io
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig
from repro.core.namespace import XufsClient
from repro.data.batches import batch_shapes


def synth_tokens(seed: int, shard: int, n: int, vocab: int) -> np.ndarray:
    """Deterministic Zipf-distributed token stream.

    The skewed unigram distribution gives the stream learnable statistics
    (entropy well below ``ln(vocab)``), so a working trainer measurably
    reduces loss on it — uniform tokens would leave nothing to learn and
    make loss-decrease checks a coin flip.
    """
    rng = np.random.default_rng(np.random.SeedSequence([seed, shard]))
    probs = 1.0 / np.arange(1, vocab + 1, dtype=np.float64)
    probs /= probs.sum()
    return rng.choice(vocab, size=n, p=probs).astype(np.int32)


@dataclass
class ShardSpec:
    index: int
    path: str
    tokens: int


class SyntheticCorpus:
    """Writes deterministic token shards into a home store via a client."""

    def __init__(self, client: XufsClient, prefix: str, *, seed: int,
                 vocab: int, shard_tokens: int = 262_144):
        self.client = client
        self.prefix = prefix.rstrip("/")
        self.seed = seed
        self.vocab = vocab
        self.shard_tokens = shard_tokens

    def shard_path(self, i: int) -> str:
        return f"{self.prefix}/shard_{i:06d}.npy"

    def materialize(self, n_shards: int) -> List[ShardSpec]:
        specs = []
        for i in range(n_shards):
            toks = synth_tokens(self.seed, i, self.shard_tokens, self.vocab)
            buf = io.BytesIO()
            np.save(buf, toks, allow_pickle=False)
            with self.client.open(self.shard_path(i), "w") as f:
                f.write(buf.getvalue())
            specs.append(ShardSpec(i, self.shard_path(i), self.shard_tokens))
        self.client.sync()
        return specs


class DataPipeline:
    """Iterates model batches from XUFS-cached shards with read-ahead."""

    def __init__(self, client: XufsClient, prefix: str, cfg: ModelConfig, *,
                 batch: int, seq: int, seed: int = 0, n_shards: int = 4,
                 read_ahead: int = 1):
        self.client = client
        self.prefix = prefix.rstrip("/")
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.n_shards = n_shards
        self.read_ahead = read_ahead
        self._shard_cache: Dict[int, np.ndarray] = {}
        self._cursor = 0          # global token cursor
        self.stalls = 0

    # ---- shard access ------------------------------------------------------
    def _load_shard(self, i: int) -> np.ndarray:
        i = i % self.n_shards
        if i not in self._shard_cache:
            path = f"{self.prefix}/shard_{i:06d}.npy"
            with self.client.open(path) as f:
                self._shard_cache[i] = np.load(io.BytesIO(f.read()),
                                               allow_pickle=False)
            # bounded cache: drop shards far behind the cursor
            if len(self._shard_cache) > self.read_ahead + 2:
                oldest = min(self._shard_cache)
                if oldest != i:
                    del self._shard_cache[oldest]
        return self._shard_cache[i]

    def _take(self, n: int) -> np.ndarray:
        out = np.empty(n, np.int32)
        got = 0
        while got < n:
            shard0 = self._load_shard(0)
            st = len(shard0)
            idx = self._cursor + got
            si, off = divmod(idx, st)
            shard = self._load_shard(si)
            take = min(n - got, st - off)
            out[got:got + take] = shard[off:off + take]
            got += take
        self._cursor += n
        # read-ahead: warm the next shard through the cache
        st = len(self._load_shard(0))
        nxt = (self._cursor // st) + 1
        self._load_shard(nxt)
        return out

    # ---- batches --------------------------------------------------------------
    def next_batch(self) -> Dict[str, jax.Array]:
        shapes = batch_shapes(self.cfg, self.batch, self.seq)
        toks_shape = shapes["tokens"][0]
        n = int(np.prod(toks_shape)) + 1
        flat = self._take(n)
        tokens = flat[:-1].reshape(toks_shape)
        targets = np.concatenate([flat[1:]]).reshape(-1)[
            : int(np.prod(shapes["targets"][0]))].reshape(
            shapes["targets"][0])
        out: Dict[str, jax.Array] = {
            "tokens": jnp.asarray(tokens),
            "targets": jnp.asarray(targets),
        }
        pshape, _ = shapes["positions"]
        if len(pshape) == 3:   # VLM [3, B, S]
            pos = np.broadcast_to(np.arange(pshape[-1], dtype=np.int32),
                                  pshape[1:])
            out["positions"] = jnp.asarray(np.broadcast_to(pos, pshape))
        else:
            out["positions"] = jnp.asarray(np.broadcast_to(
                np.arange(pshape[-1], dtype=np.int32)[None], pshape))
        if "frontend" in shapes:
            fshape, fdtype = shapes["frontend"]
            rng = np.random.default_rng(self._cursor)
            out["frontend"] = jnp.asarray(
                rng.standard_normal(fshape, dtype=np.float32)).astype(fdtype)
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        while True:
            yield self.next_batch()

    # ---- resumability ----------------------------------------------------------
    def state(self) -> Dict:
        return {"cursor": self._cursor}

    def restore(self, state: Dict) -> None:
        self._cursor = int(state["cursor"])
