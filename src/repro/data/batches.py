"""Batch construction & ShapeDtypeStruct specs for every (family, shape).

Used by the smoke tests (real arrays), the data pipeline (synthetic shards)
and launch/dryrun.py (``jax.ShapeDtypeStruct`` stand-ins — weak-type
correct, shardable, no device allocation).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import ModelConfig, ShapeConfig, ENCDEC, VLM

Batch = Dict[str, jax.Array]


def vlm_patch_count(seq_len: int) -> int:
    return min(1024, max(seq_len // 4, 4))


def batch_shapes(cfg: ModelConfig, batch: int, seq: int,
                 ) -> Dict[str, Tuple[Tuple[int, ...], jnp.dtype]]:
    """{name: (shape, dtype)} for a *training/prefill* batch."""
    i32, dt = jnp.int32, jnp.dtype(cfg.dtype)
    if cfg.family == ENCDEC:
        return {
            "frontend": ((batch, seq, cfg.frontend_embed_dim), dt),
            "tokens": ((batch, seq), i32),
            "targets": ((batch, seq), i32),
            "positions": ((batch, seq), i32),
        }
    if cfg.family == VLM:
        npat = vlm_patch_count(seq)
        ntext = seq - npat
        return {
            "frontend": ((batch, npat, cfg.frontend_embed_dim), dt),
            "tokens": ((batch, ntext), i32),
            "targets": ((batch, ntext), i32),
            "positions": ((3, batch, seq), i32),
        }
    return {
        "tokens": ((batch, seq), i32),
        "targets": ((batch, seq), i32),
        "positions": ((batch, seq), i32),
    }


def make_batch(cfg: ModelConfig, batch: int, seq: int,
               key: Optional[jax.Array] = None) -> Batch:
    """Synthetic but deterministic batch with real arrays (smoke tests)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    out: Batch = {}
    for name, (shape, dtype) in batch_shapes(cfg, batch, seq).items():
        key, sub = jax.random.split(key)
        if name in ("tokens", "targets"):
            out[name] = jax.random.randint(sub, shape, 0, cfg.vocab_size,
                                           dtype=dtype)
        elif name == "positions":
            if cfg.family == VLM:
                pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32),
                                       shape[1:])
                out[name] = jnp.broadcast_to(pos[None], shape)
            else:
                out[name] = jnp.broadcast_to(
                    jnp.arange(seq, dtype=jnp.int32)[None], shape)
        else:  # frontend embeddings
            out[name] = (jax.random.normal(sub, shape, jnp.float32)
                         .astype(dtype))
    return out


def make_specs(cfg: ModelConfig, batch: int, seq: int) -> Batch:
    """ShapeDtypeStruct stand-ins (no allocation) for lowering."""
    return {name: jax.ShapeDtypeStruct(shape, dtype)
            for name, (shape, dtype) in batch_shapes(cfg, batch, seq).items()}


def decode_token_shapes(cfg: ModelConfig, batch: int,
                        ) -> Tuple[Tuple[int, ...], jnp.dtype]:
    return (batch, 1), jnp.int32


def make_decode_tokens(cfg: ModelConfig, batch: int,
                       key: Optional[jax.Array] = None) -> jax.Array:
    key = key if key is not None else jax.random.PRNGKey(1)
    shape, dtype = decode_token_shapes(cfg, batch)
    return jax.random.randint(key, shape, 0, cfg.vocab_size, dtype=dtype)
