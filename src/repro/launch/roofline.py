"""Roofline-term derivation from a compiled dry-run artifact.

TPU v5e single-chip constants (targets; the container only compiles):
  peak bf16 compute 197 TFLOP/s, HBM BW 819 GB/s, ICI ~50 GB/s/link.

    compute term    = HLO_FLOPs / peak            (cost_analysis, per device)
    memory term     = HLO_bytes / HBM_bw
    collective term = collective_bytes / link_bw  (parsed from HLO text)

The dominant term is the structural bottleneck the §Perf loop iterates on.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

PEAK_FLOPS = 197e12         # bf16 FLOP/s per chip
HBM_BW = 819e9              # bytes/s per chip
ICI_BW = 50e9               # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32"
                       r"|s64|u64|c64)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*|\S+\s+)?(all-gather|all-reduce|reduce-scatter"
    r"|all-to-all|collective-permute)(?:-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of every collective op in (post-SPMD) HLO text."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith(("//", "#")) or "= " not in s:
            continue
        m = _OP_RE.search(s)
        if m is None:
            continue
        if "-done(" in s:
            continue   # async completion carries no new bytes
        kind = m.group(1)
        paren = s[m.end() - 1:]
        shapes = _SHAPE_RE.findall(paren)
        if not shapes:
            # compiled HLO prints operands bare: use the result shape
            shapes = _SHAPE_RE.findall(s)[:1]
        out[kind] += sum(_shape_bytes(d, dims) for d, dims in shapes)
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


def roofline_terms(flops_per_device: float, bytes_per_device: float,
                   coll_bytes_per_device: float) -> Dict[str, float]:
    t_c = flops_per_device / PEAK_FLOPS
    t_m = bytes_per_device / HBM_BW
    t_x = coll_bytes_per_device / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    total = max(t_c, t_m, t_x)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "step_lower_bound_s": total,
        "roofline_fraction_compute": t_c / total if total > 0 else 0.0,
    }


def model_flops(n_params_active: int, tokens: int, *, train: bool) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D forward-only."""
    return (6.0 if train else 2.0) * n_params_active * tokens
