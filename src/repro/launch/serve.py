"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Publishes weights into the home store, restores them at the serving site
through the XUFS fabric (striped fetch + small-tensor prefetch), and runs
a continuous-batching workload of synthetic requests.
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_tiny_config
from repro.core import Fabric, FabricSpec, SiteSpec
from repro.checkpoint import CheckpointManager
from repro.models import init_params
from repro.serve.engine import ServeEngine, Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-8b")
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--workdir", default="")
    args = ap.parse_args()

    cfg = (get_tiny_config(args.arch) if args.tiny
           else get_config(args.arch)).replace(param_dtype="bfloat16")
    workdir = args.workdir or tempfile.mkdtemp(prefix="xufs_serve_")
    fabric = Fabric(FabricSpec(sites=(
        SiteSpec("home", root=os.path.join(workdir, "home")),
        SiteSpec("site", root=os.path.join(workdir, "site")),
    )))
    net = fabric.network
    s = fabric.login("server")

    params = init_params(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(s.client, f"home/models/{cfg.name}")
    mgr.save(0, {"params": params})
    s.client.sync()
    clock0 = net.clock
    restored, _ = mgr.restore({"params": params})
    print(f"weights restored through XUFS in {net.clock - clock0:.2f}s WAN")

    engine = ServeEngine(cfg, restored["params"], slots=args.slots,
                         max_len=args.max_len)
    for i in range(args.requests):
        engine.add_request(Request(
            rid=i, prompt=[1 + (i * 7 + j) % (cfg.vocab_size - 2)
                           for j in range(3 + i % 5)],
            max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    ticks = 0
    while (engine.queue or any(st.active for st in engine.slot_states)):
        engine.step()
        ticks += 1
    dt = time.perf_counter() - t0
    print(f"{args.requests} requests, {engine.tokens_generated} tokens, "
          f"{ticks} ticks, {engine.tokens_generated / dt:.1f} tok/s (CPU)")


if __name__ == "__main__":
    main()
