import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (single-pod 16x16 = 256 or
multi-pod 2x16x16 = 512 placeholder devices), constructs ShapeDtypeStruct
stand-ins for params / optimizer state / inputs with their NamedShardings,
lowers the jitted step, compiles it, and records:

  * memory_analysis()  — proof the cell fits per-device HBM;
  * cost_analysis()    — per-device FLOPs / bytes for §Roofline;
  * collective op bytes parsed from the post-SPMD HLO text.

Artifacts land in experiments/artifacts/<arch>__<shape>__<mesh>.json and
are consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both]
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.config.base import (
    RunConfig, OptimConfig, ShardingConfig, SHAPES, TRAIN, PREFILL, DECODE,
)
from repro.configs import ARCH_IDS, get_config, get_shape, cells
from repro.data.batches import make_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.roofline import (
    collective_bytes, roofline_terms, model_flops,
)
from repro.models import (
    init_params, param_axes, init_cache, cache_logical_axes, decode_step,
    prefill,
)
from repro.optim import state_axes
from repro.parallel.context import sharding_ctx
from repro.parallel.sharding import (
    make_ctx, tree_shardings, batch_shardings, sanitize_shardings,
)
from repro.train.step import make_train_step, make_opt_state

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "experiments", "artifacts")

# Large models must serve/train fully sharded; small ones can keep the
# latency-friendly TP-only decode layout.
BIG_ARCHS = {"jamba-1.5-large-398b", "qwen2-vl-72b", "dbrx-132b",
             "qwen2.5-32b", "qwen3-moe-30b-a3b", "phi3-medium-14b"}


def _cell_run_config(arch: str, shape_name: str, *, policy: str,
                     micro: int) -> RunConfig:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    optim = OptimConfig()
    if arch == "jamba-1.5-large-398b":
        # 398B params: bf16 weights + blockwise-int8 moments to fit 16 GB
        cfg = cfg.replace(param_dtype="bfloat16")
        optim = OptimConfig(state_dtype="int8")
    if shape.kind in (PREFILL, DECODE):
        cfg = cfg.replace(param_dtype="bfloat16")   # serving runs bf16
    if policy == "auto":
        if shape.kind == TRAIN:
            policy = "fsdp"
        else:
            policy = "fsdp" if arch in BIG_ARCHS else "baseline"
    shard_seq = shape_name == "long_500k"
    return RunConfig(
        model=cfg, shape=shape,
        sharding=ShardingConfig(policy=policy, shard_seq=shard_seq),
        optim=optim, microbatches=micro)


def lower_cell(arch: str, shape_name: str, mesh_kind: str, *,
               policy: str = "auto", micro: Optional[int] = None,
               lps: Optional[int] = None) -> Dict[str, Any]:
    shape = get_shape(shape_name)
    if micro is None:
        micro = 4 if shape.kind == TRAIN else 1
    run = _cell_run_config(arch, shape_name, policy=policy, micro=micro)
    cfg = run.model
    if lps and cfg.num_layers % lps == 0 and cfg.family != "hybrid":
        cfg = cfg.replace(layers_per_step=lps)
        run = run.replace(model=cfg)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    ctx = make_ctx(mesh, run.sharding, decode=(shape.kind == DECODE))

    t0 = time.time()
    params_spec = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    p_axes = param_axes(cfg)
    p_shardings = sanitize_shardings(tree_shardings(ctx, p_axes),
                                     params_spec)

    if shape.kind == TRAIN:
        opt_spec = jax.eval_shape(
            lambda: make_opt_state(run, params_spec))
        o_shardings = tree_shardings(ctx, state_axes(p_axes, run.optim))
        if run.optim.grad_compress == "int8":
            o_shardings["ef_error"] = p_shardings
        o_shardings = sanitize_shardings(o_shardings, opt_spec)
        batch_spec = make_specs(cfg, shape.global_batch, shape.seq_len)
        b_shardings = batch_shardings(ctx, batch_spec)
        step = make_train_step(run)
        with sharding_ctx(ctx):
            jitted = jax.jit(step,
                             in_shardings=(p_shardings, o_shardings,
                                           b_shardings),
                             out_shardings=(p_shardings, o_shardings, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_spec, opt_spec, batch_spec)
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(cfg.param_count(active_only=True), tokens,
                         train=True)
    elif shape.kind == PREFILL:
        batch_spec = make_specs(cfg, shape.global_batch, shape.seq_len)
        batch_spec.pop("targets")
        b_shardings = batch_shardings(ctx, batch_spec)
        max_len = shape.seq_len

        def fn(p, b):
            return prefill(cfg, p, b, max_len=max_len)

        with sharding_ctx(ctx):
            jitted = jax.jit(fn, in_shardings=(p_shardings, b_shardings),
                             out_shardings=None)
            lowered = jitted.lower(params_spec, batch_spec)
        tokens = shape.global_batch * shape.seq_len
        mf = model_flops(cfg.param_count(active_only=True), tokens,
                         train=False)
    else:  # DECODE: one new token against a seq_len-deep cache
        B = shape.global_batch
        cache_spec = jax.eval_shape(
            lambda: init_cache(cfg, B, shape.seq_len))
        c_axes = cache_logical_axes(cfg, shard_seq=run.sharding.shard_seq)
        c_shardings = sanitize_shardings(tree_shardings(ctx, c_axes),
                                         cache_spec)
        tok_spec = jax.ShapeDtypeStruct((B, 1), jnp.int32)
        tok_sharding = ctx.sharding(("batch", None))

        def fn(p, t, c):
            return decode_step(cfg, p, t, c)

        with sharding_ctx(ctx):
            jitted = jax.jit(fn, in_shardings=(p_shardings, tok_sharding,
                                               c_shardings),
                             out_shardings=(None, c_shardings),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_spec, tok_spec, cache_spec)
        tokens = B
        mf = model_flops(cfg.param_count(active_only=True), tokens,
                         train=False)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = collective_bytes(hlo)          # flat (per-occurrence) reference
    loopaware = hlo_analyze(hlo)          # trip-count-aware (the real terms)

    flops_dev = float(loopaware["flops"])
    bytes_dev = float(loopaware["traffic_bytes"])
    coll_dev = float(loopaware["collective_total"])
    terms = roofline_terms(flops_dev, bytes_dev, coll_dev)
    mem_fields = {}
    for f in ("temp_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        mem_fields[f] = getattr(mem, f, None)

    art = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "devices": int(n_dev), "policy": run.sharding.policy,
        "microbatches": run.microbatches,
        "tokens": tokens,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "loopaware": loopaware,
        "xla_cost_analysis": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "collectives_flat": coll,
        "memory": mem_fields,
        "model_flops_total": mf,
        "model_flops_per_device": mf / n_dev,
        "useful_flops_ratio": (mf / n_dev) / flops_dev if flops_dev else None,
        "roofline": terms,
    }
    return art


def save_artifact(art: Dict[str, Any], outdir: str) -> str:
    os.makedirs(outdir, exist_ok=True)
    name = f"{art['arch']}__{art['shape']}__{art['mesh']}"
    if art.get("tag"):
        name += f"__{art['tag']}"
    path = os.path.join(outdir, name + ".json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    return path


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--policy", choices=("auto", "baseline", "fsdp"),
                    default="auto")
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--lps", type=int, default=None,
                    help="layers per scan step (remat grouping)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=os.path.normpath(ARTIFACTS))
    args = ap.parse_args()

    todo = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in cells(arch):
                meshes = (["single", "multi"] if args.mesh == "both"
                          else [args.mesh])
                for mk in meshes:
                    todo.append((arch, shape_name, mk))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        meshes = (["single", "multi"] if args.mesh == "both"
                  else [args.mesh])
        todo = [(args.arch, args.shape, mk) for mk in meshes]

    if args.all:
        # one subprocess per cell: bounds compiler memory, isolates failures
        import subprocess
        failures = 0
        for arch, shape_name, mesh_kind in todo:
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--mesh", mesh_kind, "--policy", args.policy,
                   "--out", args.out]
            if args.micro is not None:
                cmd += ["--micro", str(args.micro)]
            if args.lps is not None:
                cmd += ["--lps", str(args.lps)]
            if args.tag:
                cmd += ["--tag", args.tag]
            r = subprocess.run(cmd)
            failures += 1 if r.returncode else 0
        print(f"dry-run matrix done: {len(todo) - failures}/{len(todo)} OK",
              flush=True)
        return 1 if failures else 0

    failures = 0
    for arch, shape_name, mesh_kind in todo:
        label = f"{arch} x {shape_name} x {mesh_kind}"
        try:
            art = lower_cell(arch, shape_name, mesh_kind,
                             policy=args.policy, micro=args.micro,
                             lps=args.lps)
            if args.tag:
                art["tag"] = args.tag
            path = save_artifact(art, args.out)
            r = art["roofline"]
            print(f"OK   {label}: dominant={r['dominant']} "
                  f"compute={r['compute_s']*1e3:.1f}ms "
                  f"memory={r['memory_s']*1e3:.1f}ms "
                  f"coll={r['collective_s']*1e3:.1f}ms "
                  f"compile={art['compile_s']:.0f}s -> {path}",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"FAIL {label}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
