"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Wires an assigned architecture config into the full stack: USSH session →
synthetic corpus in the home store → XUFS-cached data pipeline →
fault-monitored trainer with write-behind checkpoints.

On this CPU container use ``--tiny`` (reduced config, same code path);
the full configs are exercised via launch/dryrun.py.
"""
from __future__ import annotations

import argparse
import os
import tempfile

from repro.config import RunConfig, ShapeConfig, OptimConfig
from repro.configs import ARCH_IDS, get_config, get_tiny_config
from repro.core import Fabric, FabricSpec, MountSpec, SiteSpec
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticCorpus, DataPipeline
from repro.train import Trainer, FaultMonitor, FaultEvent


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-crash-at", type=int, default=0)
    ap.add_argument("--workdir", default="")
    args = ap.parse_args()

    cfg = get_tiny_config(args.arch) if args.tiny else get_config(args.arch)
    print(f"arch={cfg.name} params={cfg.param_count() / 1e6:.1f}M "
          f"family={cfg.family}")

    workdir = args.workdir or tempfile.mkdtemp(prefix="xufs_train_")
    fabric = Fabric(FabricSpec(sites=(
        SiteSpec("home", root=os.path.join(workdir, "home")),
        SiteSpec("site", root=os.path.join(workdir, "site")),
    )))
    net = fabric.network
    s = fabric.login("trainer",
                     mounts=[MountSpec("home/", ("home/scratch/",))])
    SyntheticCorpus(s.client, "home/data", seed=0, vocab=cfg.vocab_size,
                    shard_tokens=max(args.batch * args.seq * 4, 8192)
                    ).materialize(4)
    pipe = DataPipeline(s.client, "home/data", cfg, batch=args.batch,
                        seq=args.seq, n_shards=4)
    run = RunConfig(
        model=cfg,
        shape=ShapeConfig("cli", "train", args.seq, args.batch),
        optim=OptimConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                          total_steps=args.steps),
        microbatches=args.micro)
    schedule = []
    if args.inject_crash_at:
        schedule.append(FaultEvent(step=args.inject_crash_at, worker=0,
                                   kind="crash"))
    trainer = Trainer(run, pipe, CheckpointManager(s.client, "home/ckpt"),
                      monitor=FaultMonitor(n_workers=4, schedule=schedule),
                      ckpt_every=args.ckpt_every)
    res = trainer.train(args.steps)
    print(f"steps={res.steps_run} restarts={res.restarts} "
          f"loss {res.losses[0]:.4f} -> {res.losses[-1]:.4f}")
    print(f"WAN clock {net.clock:.1f}s bytes {net.bytes_sent:,} "
          f"checkpoints {res.checkpoints}")
    print(f"workdir: {workdir}")


if __name__ == "__main__":
    main()
