"""Loop-aware analysis of post-SPMD compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop body ONCE, which
undercounts scan-over-layers models by ~num_layers x.  This module parses
the HLO module text, builds the computation call graph (fusions, calls,
while bodies with their ``known_trip_count``), and accumulates per-device:

  * ``flops``            — 2 * prod(result dims) * contraction size per dot
                           (MXU work; elementwise VPU work excluded);
  * ``traffic_bytes``    — Σ (result + operand bytes) over materializing
                           ops, fusion-boundary semantics (fusion interiors
                           stay in registers/VMEM);
  * ``collective_bytes`` — operand bytes per collective opcode, resolved
                           through the symbol table (operands print bare).

Every quantity is multiplied by the product of enclosing loop trip counts.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "u1": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

# ops that do not materialize new traffic (metadata / aliasing / control)
_NO_TRAFFIC = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "add-dependency",
    "get-dimension-size", "partition-id", "replica-id", "iota",
}

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s*\(.*\)\s*->.*{")
_NAME = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*")
_OPCODE = re.compile(r"^\s*([\w\-]+)\(")
_SHAPE = re.compile(r"(\w+)\[([0-9,]*)\]")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS = re.compile(r"(?:calls|body|to_apply|condition)=(%[\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS = re.compile(r"(%[\w\.\-]+)")


def _shape_list(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE.findall(text):
        if dt in _DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((dt, shape))
    return out


def _nbytes(shapes: List[Tuple[str, Tuple[int, ...]]]) -> int:
    total = 0
    for dt, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


class Instr:
    __slots__ = ("name", "result_text", "opcode", "rest", "result_bytes",
                 "result_shapes")

    def __init__(self, name, result_text, opcode, rest):
        self.name = name
        self.result_text = result_text
        self.opcode = opcode
        self.rest = rest
        self.result_shapes = _shape_list(result_text)
        self.result_bytes = _nbytes(self.result_shapes)


def _split_instr(line: str):
    """'%x = TYPE opcode(rest' -> (name, type_text, opcode, rest) or None.

    TYPE may be a tuple '(s32[], /*index=1*/f32[2]{0})' (parens + '='-laden
    comments) or a plain 'f32[8,512]{1,0}' token, so we skip it structurally
    rather than with a regex.
    """
    m = _NAME.match(line)
    if m is None:
        return None
    pos = m.end()
    n = len(line)
    if pos < n and line[pos] == "(":
        depth = 0
        start = pos
        while pos < n:
            if line[pos] == "(":
                depth += 1
            elif line[pos] == ")":
                depth -= 1
                if depth == 0:
                    pos += 1
                    break
            pos += 1
        type_text = line[start:pos]
    else:
        start = pos
        while pos < n and not line[pos].isspace():
            pos += 1
        type_text = line[start:pos]
    mo = _OPCODE.match(line[pos:])
    if mo is None:
        return None
    opcode = mo.group(1)
    rest = line[pos + mo.end():]
    return m.group(1), type_text, opcode, rest


def parse_module(text: str) -> Tuple[Dict[str, List[Instr]], str]:
    comps: Dict[str, List[Instr]] = {}
    entry = ""
    cur: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY"):
                    entry = cur
            continue
        if s == "}":
            cur = None
            continue
        parts = _split_instr(line)
        if parts:
            comps[cur].append(Instr(*parts))
    return comps, entry


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_module(text)
    # symbol table: per computation, name -> result bytes
    sym: Dict[str, Dict[str, int]] = {
        c: {i.name: i.result_bytes for i in instrs}
        for c, instrs in comps.items()
    }

    # multipliers via DFS over the call graph; fusion bodies count flops
    # but not traffic (their interiors stay in registers/VMEM)
    mult: Dict[str, float] = defaultdict(float)
    fusion_mult: Dict[str, float] = defaultdict(float)

    def visit(comp: str, m: float, in_fusion: bool) -> None:
        if in_fusion:
            fusion_mult[comp] += m
        else:
            mult[comp] += m
        for instr in comps.get(comp, ()):
            trip = 1.0
            if instr.opcode == "while":
                t = _TRIP.search(instr.rest)
                trip = float(t.group(1)) if t else 1.0
            child_fusion = in_fusion or instr.opcode in (
                "fusion", "reduce", "all-reduce", "reduce-scatter",
                "scatter", "sort", "map", "reduce-window")
            for callee in _CALLS.findall(instr.rest):
                if callee in comps:
                    visit(callee,
                          m * (trip if instr.opcode == "while" else 1.0),
                          child_fusion)

    if entry:
        visit(entry, 1.0, False)

    flops = 0.0
    traffic = 0.0
    coll: Dict[str, float] = {c: 0.0 for c in COLLECTIVE_OPS}
    coll_count = 0.0

    _SLICING = ("dynamic-slice", "gather", "slice")
    _fusion_cache: Dict[str, float] = {}

    def fusion_traffic(callee: str, result_bytes: int) -> float:
        """HBM traffic of one fusion execution: slice-aware param reads +
        update-region-aware writes (interiors stay in registers)."""
        if callee in _fusion_cache:
            return _fusion_cache[callee] + 0.0  # reads are cacheable
        instrs = comps.get(callee, [])
        table = sym.get(callee, {})
        consumers: Dict[str, List[Instr]] = defaultdict(list)
        for ins in instrs:
            head = ins.rest.split(")", 1)[0]
            for o in _OPERANDS.findall(head):
                consumers[o].append(ins)
        reads = 0.0
        for ins in instrs:
            if ins.opcode != "parameter":
                continue
            cons = consumers.get(ins.name, [])
            if cons and all(c.opcode in _SLICING for c in cons):
                reads += sum(c.result_bytes for c in cons)
            else:
                reads += ins.result_bytes
        _fusion_cache[callee] = reads
        return reads

    def fusion_write_bytes(callee: str, result_bytes: int) -> float:
        instrs = comps.get(callee, [])
        table = sym.get(callee, {})
        root = instrs[-1] if instrs else None
        if root is not None and root.opcode == "dynamic-update-slice":
            head = root.rest.split(")", 1)[0]
            opnds = _OPERANDS.findall(head)
            if len(opnds) > 1:
                return 2.0 * table.get(opnds[1], result_bytes)
        return float(result_bytes)

    for comp, instrs in comps.items():
        m = mult.get(comp, 0.0)
        m_total = m + fusion_mult.get(comp, 0.0)
        if m_total == 0.0:
            continue
        table = sym[comp]
        for instr in instrs:
            op = instr.opcode
            if op == "dot":
                cm = _CONTRACT.search(instr.rest)
                operands = _OPERANDS.findall(instr.rest)
                lhs_bytes_shapes = None
                contract = 1
                if cm and operands:
                    lhs = operands[0]
                    # find lhs shape from its defining instr
                    for cand in instrs:
                        if cand.name == lhs and cand.result_shapes:
                            dims = cand.result_shapes[0][1]
                            idxs = [int(x) for x in cm.group(1).split(",")
                                    if x != ""]
                            for i in idxs:
                                if i < len(dims):
                                    contract *= dims[i]
                            break
                    else:
                        contract = 0
                n_out = 1
                for _, shape in instr.result_shapes:
                    for d in shape:
                        n_out *= d
                if contract:
                    flops += m_total * 2.0 * n_out * contract
                traffic += m * instr.result_bytes
                traffic += m * sum(table.get(o, 0)
                                   for o in _OPERANDS.findall(
                                       instr.rest.split("),")[0]))
                continue
            if op in COLLECTIVE_OPS or any(
                    op == c + sfx for c in COLLECTIVE_OPS
                    for sfx in ("-start",)):
                base = op.replace("-start", "")
                head = instr.rest.split(")", 1)[0]
                operand_names = _OPERANDS.findall(head)
                nb = sum(table.get(o, 0) for o in operand_names)
                if nb == 0:
                    nb = instr.result_bytes
                coll[base] += m * nb
                coll_count += m
                traffic += m * nb
                continue
            if op in _NO_TRAFFIC or op.endswith("-done"):
                continue
            head = instr.rest.split(")", 1)[0]
            opnds = _OPERANDS.findall(head)
            if op in ("dynamic-slice", "gather", "slice", "broadcast",
                      "pad", "reverse"):
                # reads/writes only the slice-sized result, not the operand
                nb = 2 * instr.result_bytes
            elif op == "dynamic-update-slice":
                # in-place update: read+write of the update region only
                upd = table.get(opnds[1], 0) if len(opnds) > 1 else 0
                nb = 2 * upd
            elif op == "scatter":
                upd = table.get(opnds[2], 0) if len(opnds) > 2 else \
                    instr.result_bytes
                nb = 2 * upd
            elif op == "fusion":
                callee = None
                cm2 = _CALLS.search(instr.rest)
                if cm2:
                    callee = cm2.group(1)
                if callee and callee in comps:
                    nb = (fusion_write_bytes(callee, instr.result_bytes)
                          + fusion_traffic(callee, instr.result_bytes))
                else:
                    nb = instr.result_bytes + sum(table.get(o, 0)
                                                  for o in opnds)
            else:
                # elementwise / copy / reduce / convert: result + operands
                nb = instr.result_bytes + sum(table.get(o, 0)
                                              for o in opnds)
            traffic += m * nb

    out: Dict[str, float] = {
        "flops": flops,
        "traffic_bytes": traffic,
        "collective_total": sum(coll.values()),
        "collective_count": coll_count,
    }
    for c in COLLECTIVE_OPS:
        out[f"coll_{c}"] = coll[c]
    return out
