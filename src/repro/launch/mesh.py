"""Production mesh construction.

A function (NOT a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; tests and benches see the default single device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).

    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the leading "pod"
    axis carries only data parallelism (params are pod-cached, XUFS-style),
    so its collectives are the slow-link-friendly gradient reductions.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for unit tests (requires host-platform device override)."""
    if pods:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
