"""Striped, write-behind checkpointing over the XUFS fabric.

Save path (async, never blocks the train step on the WAN):
  1. every leaf tensor is serialized and ``close()``d through the
     XufsClient -> one aggregated store op per leaf in the WAL;
  2. a manifest (leaf paths, shapes, dtypes, step) is written AFTER all
     leaves — WAL FIFO order guarantees the manifest reaches home only
     once every leaf it references is durable (**last-close-wins commit**);
  3. the LATEST pointer is written after the manifest.
  A crash at any point replays cleanly: ``client.sync()`` drains the WAL
  in order; a LATEST that made it home always names a complete manifest.

Restore: LATEST -> manifest -> leaves; small leaves ride the parallel
prefetcher, large ones the striped fetch — the paper's Fig.4/Fig.5 split.
"""
from __future__ import annotations

import io
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.namespace import XufsClient

Params = Any


def _leaf_paths(tree: Any) -> List[Tuple[Tuple, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return flat


def _path_str(path: Tuple) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _encode(arr: jax.Array) -> bytes:
    a = np.asarray(arr)
    if a.dtype == jnp.bfloat16:   # numpy can't serialize ml_dtypes natively
        a = a.view(np.uint16)
    buf = io.BytesIO()
    np.save(buf, a, allow_pickle=False)
    return buf.getvalue()


def _decode(data: bytes, dtype: str = "") -> np.ndarray:
    a = np.load(io.BytesIO(data), allow_pickle=False)
    if dtype == "bfloat16":
        a = a.view(jnp.bfloat16)
    return a


class CheckpointManager:
    def __init__(self, client: XufsClient, prefix: str, keep: int = 3):
        self.client = client
        self.prefix = prefix.rstrip("/")
        self.keep = keep

    # ---- save -----------------------------------------------------------
    def save(self, step: int, tree: Params, *,
             extra: Optional[Dict] = None) -> str:
        base = f"{self.prefix}/step_{step:08d}"
        manifest: Dict[str, Any] = {"step": step, "leaves": [],
                                    "extra": extra or {}}
        for path, leaf in _leaf_paths(tree):
            name = _path_str(path)
            obj = f"{base}/{name}.npy"
            arr = np.asarray(leaf)
            with self.client.open(obj, "w") as f:
                f.write(_encode(arr))
            manifest["leaves"].append(
                {"name": name, "path": obj, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        with self.client.open(f"{base}/MANIFEST.json", "w") as f:
            f.write(json.dumps(manifest).encode())
        with self.client.open(f"{self.prefix}/LATEST", "w") as f:
            f.write(str(step).encode())
        return base

    # ---- restore ----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        try:
            with self.client.open(f"{self.prefix}/LATEST") as f:
                return int(f.read().decode())
        except FileNotFoundError:
            return None

    def restore(self, template: Params, step: Optional[int] = None,
                ) -> Tuple[Params, Dict]:
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError("no checkpoint published")
        base = f"{self.prefix}/step_{step:08d}"
        with self.client.open(f"{base}/MANIFEST.json") as f:
            manifest = json.loads(f.read().decode())
        by_name = {l["name"]: l for l in manifest["leaves"]}
        # parallel-prefetch the small leaves (norm scales, biases)
        self.client.chdir(base + "/")

        def load(path, leaf):
            name = _path_str(path)
            rec = by_name[name]
            with self.client.open(rec["path"]) as f:
                arr = _decode(f.read(), rec["dtype"])
            assert list(arr.shape) == rec["shape"], (name, arr.shape)
            return jnp.asarray(arr, dtype=leaf.dtype if hasattr(
                leaf, "dtype") else arr.dtype)

        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        leaves = [load(path, leaf) for path, leaf in flat]
        tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves)
        return tree, manifest

    # ---- gc -----------------------------------------------------------------
    def list_steps(self) -> List[int]:
        steps = set()
        for e in self.client.listdir_cached(self.prefix):
            parts = e.path[len(self.prefix) + 1:].split("/")
            if parts and parts[0].startswith("step_"):
                steps.add(int(parts[0][5:]))
        return sorted(steps)

    def gc(self) -> int:
        steps = self.list_steps()
        doomed = steps[:-self.keep] if len(steps) > self.keep else []
        n = 0
        for s in doomed:
            base = f"{self.prefix}/step_{s:08d}"
            for e in self.client.listdir_cached(base):
                self.client.unlink(e.path)
                n += 1
        return n
