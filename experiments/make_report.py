"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from artifacts.

Usage: PYTHONPATH=src python experiments/make_report.py > experiments/tables.md
"""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts")
HBM = 16e9


def load():
    base, opt = {}, {}
    for p in sorted(glob.glob(os.path.join(ART, "*.json"))):
        a = json.load(open(p))
        key = (a["arch"], a["shape"], a["mesh"])
        (opt if a.get("tag") == "opt" else base)[key] = a
    return base, opt


def fit(a):
    m = a["memory"]
    used = ((m["temp_size_in_bytes"] or 0)
            + (m["argument_size_in_bytes"] or 0)) / 1e9
    return used, "fits" if used < 16.0 else "OVER"


def main():
    base, opt = load()
    print("### Dry-run matrix (single-pod 256 + multi-pod 512 chips)\n")
    print("| arch | shape | mesh | policy | GB/dev (base) | GB/dev (opt) |"
          " compile_s |")
    print("|---|---|---|---|---|---|---|")
    for key in sorted(base):
        a = base[key]
        o = opt.get(key)
        gb_b, f_b = fit(a)
        gb_o, f_o = fit(o) if o else (None, "-")
        gtxt = f"{gb_o:.1f} ({f_o})" if o else "-"
        print(f"| {key[0]} | {key[1]} | {key[2]} | {a['policy']} "
              f"| {gb_b:.1f} ({f_b}) | {gtxt} | {a['compile_s']:.0f} |")

    print("\n### Roofline terms (single-pod; seconds/step lower bounds)\n")
    print("| arch | shape | variant | compute_s | memory_s | collective_s |"
          " dominant | compute-roofline frac | useful-flops ratio |")
    print("|---|---|---|---|---|---|---|---|---|")
    for key in sorted(base):
        if key[2] != "single":
            continue
        for label, src in (("paper-faithful", base), ("optimized", opt)):
            a = src.get(key)
            if a is None:
                continue
            r = a["roofline"]
            ur = a.get("useful_flops_ratio")
            print(f"| {key[0]} | {key[1]} | {label} "
                  f"| {r['compute_s']:.3f} | {r['memory_s']:.3f} "
                  f"| {r['collective_s']:.3f} | {r['dominant']} "
                  f"| {r['roofline_fraction_compute']:.3f} "
                  f"| {ur:.3f} |" if ur else "| - |")

    print("\n### Multi-pod (2 pods / 512 chips) collective deltas\n")
    print("| arch | shape | coll_s single | coll_s multi | pod-axis cost |")
    print("|---|---|---|---|---|")
    for key in sorted(base):
        if key[2] != "single":
            continue
        m_key = (key[0], key[1], "multi")
        if m_key not in base:
            continue
        cs = base[key]["roofline"]["collective_s"]
        cm = base[m_key]["roofline"]["collective_s"]
        print(f"| {key[0]} | {key[1]} | {cs:.3f} | {cm:.3f} "
              f"| {cm - cs:+.3f} |")


if __name__ == "__main__":
    main()
