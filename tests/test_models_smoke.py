"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions, and prefill+decode == full-forward parity."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_tiny_config, cells
from repro.data.batches import make_batch
from repro.models import (
    init_params, param_axes, forward, loss_fn, prefill, decode_step,
    init_cache,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _tiny(arch):
    cfg = get_tiny_config(arch)
    if cfg.moe:
        # drop-free capacity so split-batch paths agree exactly
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=16.0))
    return cfg


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = _tiny(arch)
    p = init_params(cfg, KEY)
    batch = make_batch(cfg, B, S)
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b))(p, batch)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = loss_fn(cfg, p, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = _tiny(arch).replace(remat="full")
    p = init_params(cfg, KEY)
    batch = make_batch(cfg, B, S)
    g = jax.jit(jax.grad(lambda p, b: loss_fn(cfg, p, b)[0]))(p, batch)
    for leaf in jax.tree.leaves(g):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """prefill(S-1 tokens) + decode(last) == forward(S tokens)[-1]."""
    cfg = _tiny(arch)
    p = init_params(cfg, KEY)
    batch = make_batch(cfg, B, S)
    logits, _ = jax.jit(lambda p, b: forward(cfg, p, b))(p, batch)
    b_prefix = dict(batch)
    b_prefix["tokens"] = batch["tokens"][:, :-1]
    b_prefix["targets"] = batch["targets"][:, :-1]
    if batch["positions"].ndim == 3:
        b_prefix["positions"] = batch["positions"][:, :, :-1]
    else:
        b_prefix["positions"] = batch["positions"][:, :-1]
    _, cache = jax.jit(lambda p, b: prefill(cfg, p, b, max_len=S + 8))(
        p, b_prefix)
    dec, cache2 = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c))(
        p, batch["tokens"][:, -1:], cache)
    err = float(jnp.max(jnp.abs(dec[:, 0] - logits[:, -1])))
    assert err < 1e-2, err
    # prefix held S-1 total positions (incl. frontend patches); +1 decode
    assert int(cache2["index"][0]) == S


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_axes_tree_matches_params(arch):
    cfg = _tiny(arch)
    p = init_params(cfg, KEY)
    axes = param_axes(cfg)
    flat_p = jax.tree_util.tree_flatten_with_path(p)[0]
    flat_a = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    assert len(flat_p) == len(flat_a)
    for (pp, leaf), (pa, ax) in zip(flat_p, flat_a):
        assert pp == pa, (pp, pa)
        assert len(ax) == leaf.ndim, (pp, ax, leaf.shape)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # flattened projection dims divide the 16-way model axis (DESIGN §5)
    assert cfg.q_dim % 16 == 0
    assert cfg.kv_dim % 16 == 0
    if cfg.is_moe:
        assert cfg.moe.num_experts % 16 == 0 or cfg.moe.num_experts == 16
    # long_500k only for sub-quadratic archs
    assert ("long_500k" in cells(arch)) == cfg.sub_quadratic


def test_vlm_mrope_positions_change_output():
    cfg = _tiny("qwen2-vl-72b")
    p = init_params(cfg, KEY)
    batch = make_batch(cfg, B, S)
    lo1, _ = forward(cfg, p, batch)
    b2 = dict(batch)
    b2["positions"] = batch["positions"] * jnp.asarray([1, 2, 3])[:, None, None]
    lo2, _ = forward(cfg, p, b2)
    assert float(jnp.max(jnp.abs(lo1 - lo2))) > 1e-6


def test_moe_capacity_drops_tokens_when_tight():
    cfg = get_tiny_config("qwen3-moe-30b-a3b")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    p = init_params(cfg, KEY)
    batch = make_batch(cfg, B, S)
    logits, aux = forward(cfg, p, batch)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert float(aux) > 0.0   # aux loss present
