"""Replica fabric: nearest-replica reads, fan-out, fault injection.

Faults exercised: a replica partitioned mid-striped-fetch (fallback to
home), a flusher crash between the home apply and the replica fan-out
(``replay()`` converges), and a callback-invalidated replica (never read).
"""
import pytest

from repro.core import (
    DisconnectedError, LinkModel, MB, Network, ussh_login,
)

HOME_LATENCY = 0.060


def login(tmp_path, replica_sites, tag="a"):
    net = Network(link=LinkModel(latency_s=HOME_LATENCY))
    return ussh_login("sci", net, str(tmp_path / f"home-{tag}"),
                      str(tmp_path / f"site-{tag}"),
                      replica_sites=replica_sites)


@pytest.fixture()
def rsession(tmp_path):
    """Two replicas: r1 is nearest, then r2; home is farthest."""
    return login(tmp_path, {"r1": 0.005, "r2": 0.015})


def seed_and_sync(s, path="home/data/a.bin", payload=b"A" * (1 * MB)):
    s.server.store.put(s.token, path, payload)
    s.replicas.resync()
    return path, payload


# ---- nearest-replica reads -------------------------------------------------

def test_cold_read_fills_from_nearest_replica(rsession):
    s = rsession
    path, payload = seed_and_sync(s)
    with s.client.open(path) as f:
        assert f.read() == payload
    assert s.client.cache.fills_from == {"r1": 1}       # nearest, not home


def test_replica_read_is_faster_than_home_baseline(tmp_path):
    base = login(tmp_path, None, tag="base")
    rep = login(tmp_path, {"r1": 0.005}, tag="rep")
    payload = b"B" * (4 * MB)
    for s in (base, rep):
        s.server.store.put(s.token, "home/d/x.bin", payload)
    rep.replicas.resync()
    times = {}
    for name, s in (("base", base), ("rep", rep)):
        t0 = s.client.network.clock
        with s.client.open("home/d/x.bin") as f:
            assert f.read() == payload
        times[name] = s.client.network.clock - t0
    assert times["rep"] < times["base"]


def test_cold_read_survives_home_partition_via_replica(rsession):
    """The multi-site headline: home down, a fresh replica still serves."""
    s = rsession
    path, payload = seed_and_sync(s)
    s.client.network.partition("site", "home")
    with s.client.open(path) as f:                      # never cached before
        assert f.read() == payload
    assert s.client.cache.fills_from == {"r1": 1}


def test_prefetch_waves_route_to_replica(rsession):
    s = rsession
    for i in range(8):
        s.server.store.put(s.token, f"home/src/s{i}.c", b"c" * 1000)
    s.replicas.resync()
    assert s.client.chdir("home/src") == 8
    assert s.client.cache.fills_from.get("r1") == 8
    assert s.client.network.per_endpoint_rpcs.get("r1", 0) >= 8


# ---- fault: partition mid-striped-fetch ------------------------------------

def test_partition_mid_striped_fetch_falls_back_to_home(tmp_path):
    s = login(tmp_path, {"r1": 0.005})
    path, payload = seed_and_sync(s, payload=b"S" * (2 * MB))  # striped size
    rep = s.replicas.replicas["r1"]
    orig_get = rep.store.get

    def get_then_die(token, p):
        out = orig_get(token, p)
        # the link drops after the replica starts serving, while the
        # striped transfer is still in flight
        s.client.network.partition("site", "r1")
        return out

    rep.store.get = get_then_die
    try:
        with s.client.open(path) as f:
            assert f.read() == payload                  # degraded, not error
    finally:
        rep.store.get = orig_get
    assert s.client.cache.fills_from == {"home": 1}
    # entry is fully valid despite the mid-fetch fault
    assert s.client.cache.lookup(path).state == "valid"


def test_all_sources_partitioned_raises_disconnected(tmp_path):
    s = login(tmp_path, {"r1": 0.005})
    path, _ = seed_and_sync(s)
    s.client.network.partition("site", "r1")
    s.client.network.partition("site", "home")
    with pytest.raises(DisconnectedError):
        s.client.open(path)


# ---- fault: flusher crash between home apply and fan-out -------------------

def test_flusher_crash_then_replay_converges_replicas(rsession):
    s = rsession
    payload = b"W" * 300_000
    with s.client.open("home/out/r.dat", "w") as f:
        f.write(payload)

    real_propagate = s.replicas.propagate

    def crash(path, data, st):
        raise RuntimeError("flusher crashed after home apply")

    s.replicas.propagate = crash
    with pytest.raises(RuntimeError):
        s.client.pump()
    s.replicas.propagate = real_propagate

    # home applied, replicas did not, record still pending (not marked done)
    assert s.server.store.get(s.token, "home/out/r.dat")[0] == payload
    for rep in s.replicas.replicas.values():
        with pytest.raises(FileNotFoundError):
            rep.store.get(rep.token, "home/out/r.dat")
    assert [r.path for r in s.client.oplog.pending()] == ["home/out/r.dat"]

    assert s.client.replay() == 1
    assert s.client.oplog.pending() == []
    home_v = s.server.store.stat(s.token, "home/out/r.dat").version
    for name, rep in s.replicas.replicas.items():
        data, st = rep.store.get(rep.token, "home/out/r.dat")
        assert data == payload
        assert st.version == home_v                      # converged versions
        assert name in s.replicas.catalog.fresh_holders("home/out/r.dat")


def test_partitioned_replica_never_blocks_flush_and_resyncs(rsession):
    s = rsession
    with s.client.open("home/out/lag.dat", "w") as f:
        f.write(b"L" * 200_000)
    s.client.network.partition("home", "r1")
    assert s.client.pump() == 1                          # flush not blocked
    assert s.server.store.get(s.token, "home/out/lag.dat")[0] \
        == b"L" * 200_000
    # r2 fresh, r1 lagging and out of the read path
    assert s.replicas.catalog.fresh_holders("home/out/lag.dat") == ["r2"]
    assert "home/out/lag.dat" in s.replicas.replicas["r1"].lagging
    s.client.network.heal("home", "r1")
    s.replicas.resync()
    assert sorted(s.replicas.catalog.fresh_holders("home/out/lag.dat")) \
        == ["r1", "r2"]


# ---- fault: stale (callback-invalidated) replica ---------------------------

def test_invalidated_replica_is_never_read(rsession):
    s = rsession
    path, _ = seed_and_sync(s, payload=b"v1" * 1000)
    with s.client.open(path) as f:                       # fill from r1
        f.read()
    # home changes directly; replicas still hold v1
    s.server.store.put(s.token, path, b"v2-new" * 1000)
    assert s.client.pump_callbacks() >= 1
    assert s.client.cache.lookup(path).state == "invalid"
    assert s.replicas.catalog.fresh_holders(path) == []  # all replicas stale
    with s.client.open(path) as f:
        assert f.read() == b"v2-new" * 1000              # re-fetched fresh
    assert s.client.cache.fills_from.get("home") == 1    # served by home
    assert s.client.cache.fills_from.get("r1") == 1      # only the v1 fill


def test_deleted_at_home_drops_replicas_from_read_path(rsession):
    s = rsession
    path, _ = seed_and_sync(s)
    s.server.store.delete(s.token, path)
    assert s.replicas.catalog.fresh_holders(path) == []
    with pytest.raises(FileNotFoundError):
        s.client._fetch(s.client._mount_for(path), path)


# ---- write fan-out end-to-end ---------------------------------------------

def test_write_back_fan_out_reaches_all_replicas(rsession):
    s = rsession
    with s.client.open("home/out/fan.dat", "w") as f:
        f.write(b"F" * 150_000)
    assert s.client.pump() == 1
    for rep in s.replicas.replicas.values():
        assert rep.store.get(rep.token, "home/out/fan.dat")[0] \
            == b"F" * 150_000
    # a later cold read on a fresh client cache hits the nearest replica
    import os
    os.remove(s.client.cache.data_path("home/out/fan.dat"))
    os.remove(s.client.cache.attr_path("home/out/fan.dat"))
    with s.client.open("home/out/fan.dat") as f:
        assert f.read() == b"F" * 150_000
    assert s.client.cache.fills_from.get("r1") == 1
