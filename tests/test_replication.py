"""Replica fabric: nearest-replica reads, fan-out, fault injection.

Faults exercised: a replica partitioned mid-striped-fetch (fallback to
home), a flusher crash between the home apply and the replica fan-out
(``replay()`` converges), and a callback-invalidated replica (never read).
"""
import pytest

from repro.core import (
    DisconnectedError, Fabric, FabricSpec, FaultInjector, FaultPlan,
    LinkModel, MB, PartitionEvent, ReplicaPolicy,
)

HOME_LATENCY = 0.060

#: Fault-plan outage window comfortably covering a quorum write + read
#: (virtual seconds); the plan auto-heals once the clock passes it.
OUTAGE_S = 120.0


def arm_home_outage(s, t0):
    """Declaratively cut home off from everyone (site, r1, r2) for
    ``OUTAGE_S`` starting at ``t0`` — the FaultPlan replacement for the
    old hand-rolled partition/heal loops."""
    plan = FaultPlan(events=tuple(
        PartitionEvent(at_s=t0, a=a, b=b, duration_s=OUTAGE_S)
        for a, b in (("site", "home"), ("home", "r1"), ("home", "r2"))))
    s.client.network.arm_faults(FaultInjector(s.client.network, plan))


def login(tmp_path, replica_sites, tag="a", write_quorum=1):
    fab = Fabric(FabricSpec.star(str(tmp_path / f"home-{tag}"),
                                 str(tmp_path / f"site-{tag}"),
                                 replica_latencies=replica_sites,
                                 link=LinkModel(latency_s=HOME_LATENCY)))
    policy = ReplicaPolicy(sites=tuple(replica_sites),
                           write_quorum=write_quorum) \
        if replica_sites else None
    return fab.login("sci", replicas=policy)


@pytest.fixture()
def rsession(tmp_path):
    """Two replicas: r1 is nearest, then r2; home is farthest."""
    return login(tmp_path, {"r1": 0.005, "r2": 0.015})


def seed_and_sync(s, path="home/data/a.bin", payload=b"A" * (1 * MB)):
    s.server.store.put(s.token, path, payload)
    s.replicas.resync()
    return path, payload


# ---- nearest-replica reads -------------------------------------------------

def test_cold_read_fills_from_nearest_replica(rsession):
    s = rsession
    path, payload = seed_and_sync(s)
    with s.client.open(path) as f:
        assert f.read() == payload
    assert s.client.cache.fills_from == {"r1": 1}       # nearest, not home


def test_replica_read_is_faster_than_home_baseline(tmp_path):
    base = login(tmp_path, None, tag="base")
    rep = login(tmp_path, {"r1": 0.005}, tag="rep")
    payload = b"B" * (4 * MB)
    for s in (base, rep):
        s.server.store.put(s.token, "home/d/x.bin", payload)
    rep.replicas.resync()
    times = {}
    for name, s in (("base", base), ("rep", rep)):
        t0 = s.client.network.clock
        with s.client.open("home/d/x.bin") as f:
            assert f.read() == payload
        times[name] = s.client.network.clock - t0
    assert times["rep"] < times["base"]


def test_cold_read_survives_home_partition_via_replica(rsession):
    """The multi-site headline: home down, a fresh replica still serves."""
    s = rsession
    path, payload = seed_and_sync(s)
    s.client.network.partition("site", "home")
    with s.client.open(path) as f:                      # never cached before
        assert f.read() == payload
    assert s.client.cache.fills_from == {"r1": 1}


def test_prefetch_waves_route_to_replica(rsession):
    s = rsession
    for i in range(8):
        s.server.store.put(s.token, f"home/src/s{i}.c", b"c" * 1000)
    s.replicas.resync()
    assert s.client.chdir("home/src") == 8
    assert s.client.cache.fills_from.get("r1") == 8
    assert s.client.network.per_endpoint_rpcs.get("r1", 0) >= 8


# ---- fault: partition mid-striped-fetch ------------------------------------

def test_partition_mid_striped_fetch_falls_back_to_home(tmp_path):
    s = login(tmp_path, {"r1": 0.005})
    path, payload = seed_and_sync(s, payload=b"S" * (2 * MB))  # striped size
    rep = s.replicas.replicas["r1"]
    orig_get = rep.store.get

    def get_then_die(token, p):
        out = orig_get(token, p)
        # the link drops after the replica starts serving, while the
        # striped transfer is still in flight
        s.client.network.partition("site", "r1")
        return out

    rep.store.get = get_then_die
    try:
        with s.client.open(path) as f:
            assert f.read() == payload                  # degraded, not error
    finally:
        rep.store.get = orig_get
    assert s.client.cache.fills_from == {"home": 1}
    # entry is fully valid despite the mid-fetch fault
    assert s.client.cache.lookup(path).state == "valid"


def test_all_sources_partitioned_raises_disconnected(tmp_path):
    s = login(tmp_path, {"r1": 0.005})
    path, _ = seed_and_sync(s)
    s.client.network.partition("site", "r1")
    s.client.network.partition("site", "home")
    with pytest.raises(DisconnectedError):
        s.client.open(path)


# ---- fault: flusher crash between home apply and fan-out -------------------

def test_flusher_crash_then_replay_converges_replicas(rsession):
    s = rsession
    payload = b"W" * 300_000
    with s.client.open("home/out/r.dat", "w") as f:
        f.write(payload)

    real_begin = s.replicas.begin_apply

    def crash(name, path, data, version, src=None, vts=None):
        raise RuntimeError("flusher crashed after home apply")

    s.replicas.begin_apply = crash
    with pytest.raises(RuntimeError):
        s.client.pump()
    s.replicas.begin_apply = real_begin

    # home applied, replicas did not, record still pending (not marked done)
    assert s.server.store.get(s.token, "home/out/r.dat")[0] == payload
    for rep in s.replicas.replicas.values():
        with pytest.raises(FileNotFoundError):
            rep.store.get(rep.token, "home/out/r.dat")
    assert [r.path for r in s.client.oplog.pending()] == ["home/out/r.dat"]

    assert s.client.replay() == 1
    assert s.client.oplog.pending() == []
    home_v = s.server.store.stat(s.token, "home/out/r.dat").version
    for name, rep in s.replicas.replicas.items():
        data, st = rep.store.get(rep.token, "home/out/r.dat")
        assert data == payload
        assert st.version == home_v                      # converged versions
        assert name in s.replicas.catalog.fresh_holders("home/out/r.dat")


def test_partitioned_replica_never_blocks_flush_and_resyncs(rsession):
    s = rsession
    with s.client.open("home/out/lag.dat", "w") as f:
        f.write(b"L" * 200_000)
    s.client.network.partition("home", "r1")
    assert s.client.pump() == 1                          # flush not blocked
    assert s.server.store.get(s.token, "home/out/lag.dat")[0] \
        == b"L" * 200_000
    # r2 fresh, r1 lagging and out of the read path
    assert s.replicas.catalog.fresh_holders("home/out/lag.dat") == ["r2"]
    assert "home/out/lag.dat" in s.replicas.replicas["r1"].lagging
    s.client.network.heal("home", "r1")
    s.replicas.resync()
    assert sorted(s.replicas.catalog.fresh_holders("home/out/lag.dat")) \
        == ["r1", "r2"]


# ---- fault: stale (callback-invalidated) replica ---------------------------

def test_invalidated_replica_is_never_read(rsession):
    s = rsession
    path, _ = seed_and_sync(s, payload=b"v1" * 1000)
    with s.client.open(path) as f:                       # fill from r1
        f.read()
    # home changes directly; replicas still hold v1
    s.server.store.put(s.token, path, b"v2-new" * 1000)
    assert s.client.pump_callbacks() >= 1
    assert s.client.cache.lookup(path).state == "invalid"
    assert s.replicas.catalog.fresh_holders(path) == []  # all replicas stale
    with s.client.open(path) as f:
        assert f.read() == b"v2-new" * 1000              # re-fetched fresh
    assert s.client.cache.fills_from.get("home") == 1    # served by home
    assert s.client.cache.fills_from.get("r1") == 1      # only the v1 fill


def test_deleted_at_home_drops_replicas_from_read_path(rsession):
    s = rsession
    path, _ = seed_and_sync(s)
    s.server.store.delete(s.token, path)
    assert s.replicas.catalog.fresh_holders(path) == []
    with pytest.raises(FileNotFoundError):
        s.client._fetch(s.client._mount_for(path), path)


# ---- quorum-acknowledged writes --------------------------------------------

def qlogin(tmp_path, write_quorum, tag="q"):
    return login(tmp_path, {"r1": 0.005, "r2": 0.015}, tag=tag,
                 write_quorum=write_quorum)


def test_flusher_crash_after_partial_acks_resumes_from_persisted_acks(
        tmp_path):
    """Crash after W-1 acks: the persisted ack set is the resume point —
    replay never re-contacts an endpoint that already confirmed."""
    s = qlogin(tmp_path, "majority")           # N=3 -> W=2
    payload = b"Q" * 200_000
    with s.client.open("home/out/q.dat", "w") as f:
        f.write(payload)

    real_begin = s.replicas.begin_apply

    def crash_before_any_replica(name, path, data, version, src=None, vts=None):
        raise RuntimeError("flusher crashed after the home ack (W-1=1)")

    s.replicas.begin_apply = crash_before_any_replica
    with pytest.raises(RuntimeError):
        s.client.pump()
    s.replicas.begin_apply = real_begin

    # the home ack survived the crash, persisted in the WAL
    [rec] = s.client.oplog.pending()
    assert rec.acked == ["home"]
    assert rec.status == "applied@home"
    assert rec.version == s.server.store.stat(s.token,
                                              "home/out/q.dat").version

    # a fresh queue over the same WAL (new flusher process) sees the acks
    from repro.core.oplog import MetaOpQueue
    [rec2] = MetaOpQueue(s.client.oplog.root).pending()
    assert rec2.acked == ["home"] and rec2.version == rec.version

    # replay resumes from the ack set: no new traffic crosses site<->home
    home_rpcs = s.client.network.pair_rpcs("site", "home")
    assert s.client.replay() == 1
    assert s.client.network.pair_rpcs("site", "home") == home_rpcs
    assert s.client.oplog.pending() == []
    for rep in s.replicas.replicas.values():
        assert rep.store.get(rep.token, "home/out/q.dat")[0] == payload


def test_home_partitioned_whole_write_majority_quorum_still_acks(tmp_path):
    """The headline: home down for the entire write, majority still acks
    — and a cold read is served fresh from an acked replica."""
    s = qlogin(tmp_path, "majority")
    t0 = s.client.network.clock
    arm_home_outage(s, t0)
    payload = b"H" * 250_000
    path = "home/out/h.dat"
    with s.client.open(path, "w") as f:
        f.write(payload)

    assert s.client.pump() == 1                  # acked without home
    assert s.client.sync() == 0                  # client-complete: no backlog
    [rec] = s.client.oplog.unreconciled()
    assert rec.status == "quorum"
    assert sorted(rec.acked) == ["r1", "r2"]
    with pytest.raises(FileNotFoundError):
        s.server.store.get(s.token, path)        # home never saw it

    # quorum-aware read: replicas are fresh holders despite home silence
    assert sorted(s.replicas.catalog.fresh_holders(path)) == ["r1", "r2"]
    s.client.cache.evict(path)                   # force a cold fill
    with s.client.open(path) as f:
        assert f.read() == payload
    assert s.client.cache.fills_from.get("r1") == 1

    # the outage window lapses (plan auto-heal): reconnect() reattaches
    # + reconciles the parked op to home
    s.client.network.advance(t0 + OUTAGE_S - s.client.network.clock)
    s.client.reconnect()
    assert s.client.oplog.unreconciled() == []
    data, st = s.server.store.get(s.token, path)
    assert data == payload and st.version == rec.version
    assert s.replicas.catalog.home_version(path) == rec.version


def test_w_all_blocks_on_lagging_replica_until_heal(tmp_path):
    """W=all: one partitioned replica stalls the drain; partial acks are
    persisted and the op completes on the next pump after the heal."""
    s = qlogin(tmp_path, "all")
    s.client.network.partition("home", "r1")
    s.client.network.partition("site", "r1")
    payload = b"A" * 120_000
    with s.client.open("home/out/all.dat", "w") as f:
        f.write(payload)

    assert s.client.pump() == 0                  # 2/3 acks: not enough
    [rec] = s.client.oplog.pending()
    assert sorted(rec.acked) == ["home", "r2"]   # partial acks persisted
    assert s.client.sync() == 0                  # still blocked

    s.client.network.heal("home", "r1")
    s.client.network.heal("site", "r1")
    assert s.client.pump() == 1                  # only r1 is contacted now
    assert s.client.oplog.pending() == []
    data, st = s.replicas.replicas["r1"].store.get(
        s.replicas.replicas["r1"].token, "home/out/all.dat")
    assert data == payload
    assert st.version == rec.version


def test_w1_baseline_stalls_when_home_is_down(tmp_path):
    """W=1 degenerates to the legacy policy: no home, no ack — replicas
    alone never satisfy the write, exactly the gap quorum writes close."""
    s = qlogin(tmp_path, 1)
    s.client.network.partition("site", "home")
    with s.client.open("home/out/w1.dat", "w") as f:
        f.write(b"stall")
    assert s.client.pump() == 0
    assert [r.path for r in s.client.oplog.pending()] == ["home/out/w1.dat"]
    for rep in s.replicas.replicas.values():
        with pytest.raises(FileNotFoundError):
            rep.store.get(rep.token, "home/out/w1.dat")


def test_delete_after_parked_quorum_store_is_not_resurrected(tmp_path):
    """A delete that lands at home retires the quorum-parked store it
    supersedes — reconcile must not resurrect the deleted file."""
    s = qlogin(tmp_path, "majority")
    path = "home/out/gone.dat"
    s.client.network.partition("site", "home")
    with s.client.open(path, "w") as f:
        f.write(b"ghost" * 1000)
    assert s.client.pump() == 1                  # parked at quorum
    assert len(s.client.oplog.unreconciled()) == 1

    s.client.network.heal("site", "home")
    s.client.unlink(path)
    assert s.client.pump() == 1                  # delete lands at home
    assert s.client.oplog.unreconciled() == []   # parked store retired

    assert s.client.replay() == 0                # nothing left to re-drive
    with pytest.raises(FileNotFoundError):
        s.server.store.get(s.token, path)
    for rep in s.replicas.replicas.values():
        with pytest.raises(FileNotFoundError):
            rep.store.get(rep.token, path)


def test_reconcile_lands_on_top_when_catalog_undercounted_version(tmp_path):
    """A fresh client's catalog may not know home's version; its quorum
    write pins too small a version, but reconciliation must still land
    the acknowledged bytes at home — on top, never silently dropped."""
    s = qlogin(tmp_path, "majority")
    path = "home/out/vc.dat"
    for _ in range(3):                           # home holds v3
        s.server.store.put(s.token, path, b"old")
    s.replicas.resync()
    # simulate a fresh client session: the in-memory catalog starts cold
    s.replicas.catalog.home_versions.clear()
    s.replicas.catalog.quorum_versions.clear()
    s.replicas.catalog._holders.clear()

    t0 = s.client.network.clock
    arm_home_outage(s, t0)
    with s.client.open(path, "w") as f:
        f.write(b"new-bytes")
    assert s.client.pump() == 1                  # quorum at pinned v1
    [rec] = s.client.oplog.unreconciled()
    assert rec.version == 1                      # the under-count

    s.client.network.advance(t0 + OUTAGE_S - s.client.network.clock)
    s.client.reconnect()                         # reattach + reconcile
    data, st = s.server.store.get(s.token, path)
    assert data == b"new-bytes"                  # the acked write survived
    assert st.version == 4                       # landed on top of v3
    assert s.client.oplog.unreconciled() == []


def test_newer_close_retires_parked_quorum_store(tmp_path):
    """Last-close-wins extends to parked records: once a newer write to
    the same path completes, reconcile must never land the older bytes."""
    s = qlogin(tmp_path, "majority")
    path = "home/out/lww.dat"
    s.client.network.partition("site", "home")
    with s.client.open(path, "w") as f:
        f.write(b"old-quorum" * 100)
    assert s.client.pump() == 1                  # parks at quorum
    s.client.network.heal("site", "home")

    with s.client.open(path, "w") as f:
        f.write(b"new-final" * 100)
    assert s.client.pump() == 1                  # lands at home, done
    assert s.client.oplog.unreconciled() == []   # parked store retired

    s.client.replay()                            # reconcile is a no-op
    data, _st = s.server.store.get(s.token, path)
    assert data == b"new-final" * 100


def test_resync_never_clobbers_quorum_acked_replica_bytes(tmp_path):
    """Anti-entropy must not push home's numerically-higher-but-older
    version over replicas holding a quorum-acked write (nor drop a
    parked path home has never seen)."""
    s = qlogin(tmp_path, "majority")
    path = "home/out/guard.dat"
    for _ in range(3):                           # home holds v3, old bytes
        s.server.store.put(s.token, path, b"old")
    s.replicas.resync()
    # fresh-session catalog: knows nothing of v3
    s.replicas.catalog.home_versions.clear()
    s.replicas.catalog.quorum_versions.clear()
    s.replicas.catalog._holders.clear()

    s.client.network.partition("site", "home")   # home-side links stay up
    with s.client.open(path, "w") as f:
        f.write(b"acked-new")
    with s.client.open("home/out/fresh.dat", "w") as f:
        f.write(b"only-on-replicas")
    assert s.client.pump() == 2                  # both park at quorum

    s.client.replay()                            # resync runs mid-outage
    for rep in s.replicas.replicas.values():
        assert rep.store.get(rep.token, path)[0] == b"acked-new"
        assert rep.store.get(rep.token,
                             "home/out/fresh.dat")[0] == b"only-on-replicas"
    # the quorum freshness floor survived: replicas still serve the write
    assert sorted(s.replicas.catalog.fresh_holders(path)) == ["r1", "r2"]


# ---- read repair -----------------------------------------------------------

def test_read_repair_heals_stale_replica_on_quorum_read(rsession):
    """A cold read that routes past a stale replica pushes the fresh
    bytes back over the fan-out fabric — no resync() needed."""
    s = rsession
    path, _ = seed_and_sync(s)
    payload2 = b"v2" * 100_000
    s.client.network.partition("home", "r1")     # r1 misses the fan-out
    with s.client.open(path, "w") as f:
        f.write(payload2)
    assert s.client.pump() == 1
    assert s.replicas.catalog.fresh_holders(path) == ["r2"]
    s.client.network.heal("home", "r1")

    s.client.cache.evict(path)
    with s.client.open(path) as f:               # cold fill from r2
        assert f.read() == payload2
    assert s.client.cache.fills_from.get("r2") == 1
    # r1 was repaired off the read path: fresh bytes, back in the catalog
    assert s.replicas.read_repairs == 1
    rep = s.replicas.replicas["r1"]
    assert rep.store.get(rep.token, path)[0] == payload2
    assert sorted(s.replicas.catalog.fresh_holders(path)) == ["r1", "r2"]
    assert path not in rep.lagging


def test_read_repair_is_off_the_critical_path(rsession):
    """The repair push must not charge the reader's clock: a read that
    repairs costs the same as the r2 fill alone."""
    s = rsession
    path, _ = seed_and_sync(s)
    s.client.network.partition("home", "r1")
    with s.client.open(path, "w") as f:
        f.write(b"R" * 200_000)
    s.client.pump()
    s.client.network.heal("home", "r1")
    s.client.cache.evict(path)
    t0 = s.client.network.clock
    with s.client.open(path) as f:
        f.read()
    elapsed = s.client.network.clock - t0
    assert s.replicas.read_repairs == 1
    # fill rides site<->r2 (15 ms link); the site->r1 repair push and its
    # ack never land on the clock the reader saw
    fill_time = s.client.network.link_between("site", "r2").stream_time(
        200_000, concurrency=3)
    assert elapsed <= fill_time + 3 * 0.015 + 1e-9


def test_read_repair_refuses_stale_push(rsession):
    """Bytes older than the freshness floor must never propagate."""
    s = rsession
    path, payload_v1 = seed_and_sync(s)
    s.server.store.put(s.token, path, b"v2-newer")   # floor moves to v2
    assert s.replicas.read_repair("site", path, payload_v1, 1) == 0
    rep = s.replicas.replicas["r1"]
    assert rep.store.get(rep.token, path)[0] == payload_v1  # untouched


# ---- replica-aware metadata (stat / opendir) -------------------------------

def test_stat_routes_to_nearest_fresh_replica(rsession):
    s = rsession
    path, _ = seed_and_sync(s)
    net = s.client.network
    home_rpcs = net.pair_rpcs("site", "home")
    r1_rpcs = net.pair_rpcs("site", "r1")
    st = s.client.stat(path)
    assert st is not None and st.version == 1
    assert net.pair_rpcs("site", "home") == home_rpcs   # home never asked
    assert net.pair_rpcs("site", "r1") == r1_rpcs + 1


def test_stat_survives_home_partition_via_replica(rsession):
    s = rsession
    path, payload = seed_and_sync(s)
    s.client.network.partition("site", "home")
    st = s.client.stat(path)
    assert st is not None and st.size == len(payload)


def test_stat_missing_path_is_authoritative_from_home(rsession):
    s = rsession
    assert s.client.stat("home/data/never-existed") is None


def test_opendir_routes_to_fresh_replica_with_home_fallback(rsession):
    s = rsession
    for i in range(4):
        s.server.store.put(s.token, f"home/meta/f{i}.c", b"x" * 500)
    s.replicas.resync()
    net = s.client.network
    home_rpcs = net.pair_rpcs("site", "home")
    stats = s.client.opendir("home/meta")
    assert len(stats) == 4
    assert net.pair_rpcs("site", "home") == home_rpcs   # listing from r1
    assert net.pair_rpcs("site", "r1") >= 1
    # nearest replica partitioned: degrade to the next source, not error
    s.client.network.partition("site", "r1")
    stats = s.client.opendir("home/meta")
    assert len(stats) == 4


def test_opendir_sibling_dir_prefix_does_not_block_replica(rsession):
    """Directory matching, not raw string prefix: staleness in
    home/meta2 must not push home/meta listings back to home."""
    s = rsession
    for i in range(2):
        s.server.store.put(s.token, f"home/meta/f{i}.c", b"x" * 400)
    s.replicas.resync()
    s.server.store.put(s.token, "home/meta2/late.c", b"y" * 400)  # unsynced
    s.replicas.replicas["r1"].lagging.add("home/meta2/late.c")
    net = s.client.network
    home_rpcs = net.pair_rpcs("site", "home")
    stats = s.client.opendir("home/meta")
    assert len(stats) == 2
    assert net.pair_rpcs("site", "home") == home_rpcs   # replica served it


def test_opendir_cold_catalog_with_partial_knowledge_goes_home(tmp_path):
    """A fresh session's catalog has only seen its own writes — it cannot
    prove a listing complete (objects may predate the subscription), so
    metadata stays home until a resync teaches it the home vector."""
    s1 = login(tmp_path, None, tag="shared")
    s1.server.store.put(s1.token, "home/meta/old.c", b"o" * 300)
    # second login over the same home root: fresh (ignorant) catalog
    s2 = login(tmp_path, {"r1": 0.005}, tag="shared")
    with s2.client.open("home/meta/new.c", "w") as f:
        f.write(b"n" * 300)
    s2.client.sync()                             # new.c fanned out to r1
    stats = s2.client.opendir("home/meta")       # must include old.c
    assert {st.path for st in stats} == {"home/meta/old.c",
                                         "home/meta/new.c"}
    s2.replicas.resync()                         # vector learned
    hp = s2.client.network.pair_rpcs("site", "home")
    stats = s2.client.opendir("home/meta")       # now provably complete
    assert len(stats) == 2
    assert s2.client.network.pair_rpcs("site", "home") == hp


def test_opendir_falls_back_home_when_replica_listing_incomplete(rsession):
    """A path the replicas never received keeps listings at home — a
    replica must not serve a provably-incomplete directory."""
    s = rsession
    for i in range(2):
        s.server.store.put(s.token, f"home/meta2/f{i}.c", b"x" * 500)
    s.replicas.resync()
    s.server.store.put(s.token, "home/meta2/late.c", b"y" * 500)  # no resync
    net = s.client.network
    home_rpcs = net.pair_rpcs("site", "home")
    stats = s.client.opendir("home/meta2")
    assert {st.path for st in stats} >= {"home/meta2/late.c"}
    assert net.pair_rpcs("site", "home") == home_rpcs + 1


# ---- overlapped fan-out: drain time + determinism --------------------------

def test_drain_time_w1_le_majority_lt_all(tmp_path):
    """Acceptance: with the op set held fixed, overlapped fan-out makes
    the full drain (not just ack latency) order W=1 <= majority < all."""
    drain = {}
    for tag, policy in (("w1", 1), ("majority", "majority"), ("all", "all")):
        s = qlogin(tmp_path, policy, tag=f"drain-{tag}")
        for i in range(3):
            with s.client.open(f"home/out/d{i}.dat", "w") as f:
                f.write(bytes([i]) * 200_000)
        t0 = s.client.network.clock
        assert s.client.sync() == 3
        drain[tag] = s.client.network.clock - t0
        # beyond-quorum applies still landed (in the background)
        for rep in s.replicas.replicas.values():
            assert rep.store.get(rep.token, "home/out/d2.dat")[0] \
                == bytes([2]) * 200_000
    assert drain["w1"] <= drain["majority"] < drain["all"]


def test_same_ops_same_clock_and_ack_trace(tmp_path):
    """Acceptance: two identical runs produce identical channel traces,
    final clocks, and ack latencies."""

    def one_run(tag):
        s = qlogin(tmp_path, "majority", tag=tag)
        for i in range(3):
            with s.client.open(f"home/out/t{i}.dat", "w") as f:
                f.write(bytes([i + 1]) * 150_000)
        s.client.sync()
        with s.client.open("home/out/t1.dat") as f:
            f.read()
        return (s.client.network.clock, list(s.client.ack_wan_s.values()),
                s.client.network.trace)

    clock1, acks1, trace1 = one_run("det-a")
    clock2, acks2, trace2 = one_run("det-b")
    assert clock1 == clock2
    assert acks1 == acks2
    assert trace1 == trace2


# ---- write fan-out end-to-end ---------------------------------------------

def test_write_back_fan_out_reaches_all_replicas(rsession):
    s = rsession
    with s.client.open("home/out/fan.dat", "w") as f:
        f.write(b"F" * 150_000)
    assert s.client.pump() == 1
    for rep in s.replicas.replicas.values():
        assert rep.store.get(rep.token, "home/out/fan.dat")[0] \
            == b"F" * 150_000
    # a later cold read on a fresh client cache hits the nearest replica
    s.client.cache.evict("home/out/fan.dat")
    with s.client.open("home/out/fan.dat") as f:
        assert f.read() == b"F" * 150_000
    assert s.client.cache.fills_from.get("r1") == 1


# ---- congestion-aware routing + route memoization --------------------------

def test_route_candidates_memoized_with_hit_counter(rsession):
    """Repeated routes for one (client, path) reuse the memoized
    fresh-source candidates instead of rebuilding the ranked list."""
    s = rsession
    path, _ = seed_and_sync(s)
    first = [name for name, _store, _tok in s.replicas.route("site", path)]
    misses0 = s.replicas.route_misses
    hits0 = s.replicas.route_hits
    for _ in range(5):
        again = [n for n, _s, _t in s.replicas.route("site", path)]
        assert again == first
    assert s.replicas.route_hits == hits0 + 5
    assert s.replicas.route_misses == misses0


def test_route_cache_invalidated_by_catalog_change(rsession):
    """A home-side write (catalog note) must evict memoized routes: the
    stale replicas drop out of the read path immediately."""
    s = rsession
    path, _ = seed_and_sync(s)
    assert [n for n, _s, _t in s.replicas.route("site", path)][0] == "r1"
    s.replicas.route("site", path)            # populate + hit
    s.server.store.put(s.token, path, b"v2")  # note_home bumps catalog gen
    ranked = [n for n, _s, _t in s.replicas.route("site", path)]
    assert ranked == ["home"]                 # replicas stale: home only
    assert s.replicas.catalog.fresh_holders(path) == []


def test_route_cache_invalidated_by_lagging_change(rsession):
    """Direct lagging mutations (deferred fan-out, tests) take effect
    immediately — a lagging replica must leave the route NOW (lagging
    is checked per-call, never baked into the memoized candidates)."""
    s = rsession
    path, _ = seed_and_sync(s)
    assert [n for n, _s, _t in s.replicas.route("site", path)][0] == "r1"
    s.replicas.replicas["r1"].lagging.add(path)
    ranked = [n for n, _s, _t in s.replicas.route("site", path)]
    assert "r1" not in ranked
    s.replicas.replicas["r1"].lagging.discard(path)
    assert [n for n, _s, _t in s.replicas.route("site", path)][0] == "r1"


def test_queue_aware_route_sheds_saturated_replica(rsession):
    """The headline: a hammered replica (NIC backlog) sheds reads to the
    next-nearest fresh holder; static routing keeps hitting it."""
    s = rsession
    path, _ = seed_and_sync(s)
    net = s.client.network
    net.set_nic_budget("r1", 10 * MB)
    # hammer r1's NIC from elsewhere: 200 MB of backlog = 20 s
    net.transfer("r1", "home", "background", 200 * MB)
    ranked = [n for n, _s, _t in s.replicas.route("site", path,
                                                  nbytes=1 * MB)]
    assert ranked == ["r2", "home", "r1"]     # shed off the hot replica
    s.replicas.queue_aware = False            # static ranking ignores load
    ranked = [n for n, _s, _t in s.replicas.route("site", path,
                                                  nbytes=1 * MB)]
    assert ranked[0] == "r1"
    net.drain()


def test_queue_aware_idle_network_matches_static_order(rsession):
    """With nothing in flight and no budgets, estimated-completion
    ranking degenerates to the static nearest-by-latency order."""
    s = rsession
    path, _ = seed_and_sync(s)
    aware = [n for n, _s, _t in s.replicas.route("site", path)]
    s.replicas.queue_aware = False
    static = [n for n, _s, _t in s.replicas.route("site", path)]
    assert aware == static == ["r1", "r2", "home"]


def test_flusher_fanout_prefers_uncongested_replica(tmp_path):
    """Write fan-out launch order is queue-aware: with r1's NIC
    saturated, the W-th ack is collected from r2 first."""
    s = login(tmp_path, {"r1": 0.005, "r2": 0.015})
    net = s.client.network
    net.set_nic_budget("r1", 10 * MB)
    net.transfer("r1", "home", "background", 500 * MB)   # 50 s backlog
    order = s.replicas.replicas_by_cost("home", 150_000)
    assert order == ["r2", "r1"]
    net.drain()


def test_route_meta_uses_directory_index(rsession):
    """The per-directory index answers route_meta without scanning the
    whole catalog, and matches the old directory-boundary semantics."""
    s = rsession
    for i in range(3):
        s.server.store.put(s.token, f"home/idx/f{i}.c", b"x" * 100)
    s.server.store.put(s.token, "home/idx2/other.c", b"y" * 100)
    s.replicas.resync()
    cat = s.replicas.catalog
    assert cat.paths_under("home/idx/") == {f"home/idx/f{i}.c"
                                            for i in range(3)}
    assert cat.paths_under("home/") >= {"home/idx2/other.c"}
    assert cat.paths_under("home/idx") == frozenset()   # not a dir prefix
    # deletions keep their index entry but fail the freshness filter
    s.server.store.delete(s.token, "home/idx/f0.c")
    assert "home/idx/f0.c" in cat.paths_under("home/idx/")
    assert cat.freshness_floor("home/idx/f0.c") < 0


def test_lagging_bulk_mutators_invalidate_routes(rsession):
    """Every set-mutation spelling on a replica's lagging set (update,
    |=, -=, pop) is honored by the next route, not just add/discard —
    lagging is a per-call check on a plain set."""
    s = rsession
    path, _ = seed_and_sync(s)
    rep = s.replicas.replicas["r1"]
    assert [n for n, _s, _t in s.replicas.route("site", path)][0] == "r1"
    rep.lagging.update({path})
    assert "r1" not in [n for n, _s, _t in s.replicas.route("site", path)]
    rep.lagging -= {path}
    assert [n for n, _s, _t in s.replicas.route("site", path)][0] == "r1"
    rep.lagging |= {path}
    assert "r1" not in [n for n, _s, _t in s.replicas.route("site", path)]
    assert rep.lagging.pop() == path
    assert [n for n, _s, _t in s.replicas.route("site", path)][0] == "r1"


# ---- resync regressions (maintenance-plane PR) ------------------------------

def test_resync_delete_pass_clears_lagging(rsession):
    """Regression: resync's delete pass removed the replica copy but
    never cleared ``rep.lagging`` (propagate_delete did) — the dead path
    stayed on the read-repair candidate list forever."""
    s = rsession
    path, _ = seed_and_sync(s, path="home/out/dead.dat")
    net = s.client.network
    s.server.store.delete(s.token, path)
    net.partition("home", "r1")
    s.replicas.resync()                 # delete can't reach r1: deferred
    rep = s.replicas.replicas["r1"]
    assert path in rep.lagging
    assert path in s.replicas.catalog.paths_at("r1")
    net.heal("home", "r1")
    s.replicas.resync()                 # the delete lands...
    assert path not in rep.lagging      # ...and the lag clears with it
    assert path not in s.replicas.catalog.paths_at("r1")
    with pytest.raises(FileNotFoundError):
        rep.store.get(rep.token, path)


def test_resync_pins_the_version_it_fetched(rsession):
    """Regression: a home write landing between resync's vector snapshot
    and its blob fetch was applied to replicas at the *newer* fetched
    version while the catalog kept the snapshot's — home view and
    replica holdings permanently divergent whenever the change-feed
    subscription is down, which is exactly the post-crash recovery
    resync serves."""
    from repro.core.transport import respond

    s = rsession
    path, _ = seed_and_sync(s, path="home/out/race.bin")      # v1
    store = s.server.store
    store.put(s.token, path, b"B" * 100_000)                  # v2 at home
    s.server.crash()        # change feed dead: the race cannot self-heal
    token = store.authenticate(lambda ch: respond(store.keyphrase, ch))
    s.replicas.token = token          # the post-crash sync tool's state
    racing = b"C" * 120_000
    real_get = store.get
    fired = {"done": False}

    def racing_get(tok, p):
        if p == path and not fired["done"]:
            fired["done"] = True
            store.get = real_get      # the racing writer is a bystander
            store.put(token, p, racing)           # v3 lands mid-resync
        return real_get(tok, p)

    store.get = racing_get
    try:
        s.replicas.resync()
    finally:
        store.get = real_get
    assert fired["done"]
    cat = s.replicas.catalog
    st = store.stat_unchecked(path)
    assert st.version == 3
    assert cat.home_version(path) == st.version   # pinned, not snapshot
    for name in ("r1", "r2"):
        assert cat.version_at(path, name) == st.version
        rep = s.replicas.replicas[name]
        data, rst = rep.store.get(rep.token, path)
        assert data == racing and rst.version == st.version
    assert sorted(cat.fresh_holders(path)) == ["r1", "r2"]
