"""Public-API stability: ``repro.core`` exports the documented surface.

``docs/fabric.md`` documents the declarative spec layer; this test pins
the exported names so a refactor cannot silently drop (or typo) part of
the public surface.  Additions are fine — removals and renames must
update the docs and this list together.
"""
import inspect

import repro.core as core

#: The documented spec surface (docs/fabric.md).  Every name must be
#: exported, importable, and non-None.
SPEC_SURFACE = {
    "Fabric", "FabricSpec", "SiteSpec", "LinkSpec", "ReplicaPolicy",
    "EvictionSpec", "MountSpec", "Session", "UserFileServer", "ussh_login",
}

#: The long-standing core surface the spec layer composes with.
CORE_SURFACE = {
    "Network", "Endpoint", "LinkModel", "Transfer", "KeyPhrase",
    "DisconnectedError", "AuthError", "QuorumNotReachedError",
    "KB", "MB", "GB",
    "HomeStore", "ObjectStat", "CacheSpace", "CacheEntry", "CacheStats",
    "MetaOpQueue", "OpRecord", "NotificationManager",
    "PendingApply", "Replica", "ReplicaCatalog", "ReplicaSet",
    "WritePolicy", "LeaseManager", "XufsClient", "XufsFile", "Mount",
    "Prefetcher", "StripedTransfer", "TransferGroup", "StripePlan",
    "plan_stripes", "reassemble",
}

#: The maintenance plane (docs/maintenance.md).
MAINTENANCE_SURFACE = {
    "MaintenanceSpec", "MaintenanceScheduler", "MaintenanceReport",
    "RetryPolicy", "ScheduledTask", "DeadLetter", "LockTable",
}

#: Concurrent-writer safety (docs/consistency.md).
CONFLICT_SURFACE = {
    "WriteLeaseSpec", "WriteLeaseContended", "ConflictRecord",
    "vts_merge", "vts_dominates", "vts_concurrent",
}

#: The deterministic fault-injection harness (docs/maintenance.md).
FAULT_SURFACE = {
    "FaultPlan", "FaultInjector", "PartitionEvent", "FlapEvent",
    "CrashEvent", "HealEvent",
}

#: The bulk-transfer plane (docs/transport.md).
BULK_SURFACE = {
    "BulkSpec", "BulkTransfer", "BulkResult", "grant_streams",
    "ensure_channel_width",
}


def test_all_covers_documented_surface():
    missing = (SPEC_SURFACE | CORE_SURFACE | MAINTENANCE_SURFACE
               | CONFLICT_SURFACE | FAULT_SURFACE
               | BULK_SURFACE) - set(core.__all__)
    assert not missing, f"repro.core.__all__ lost exports: {sorted(missing)}"


def test_every_export_resolves():
    for name in core.__all__:
        assert getattr(core, name) is not None, f"{name} exports as None"


def test_spec_layer_signatures_are_stable():
    """The login surface the docs teach: keyword names are API."""
    params = inspect.signature(core.Fabric.login).parameters
    for kw in ("home", "site", "mounts", "replicas", "home_root",
               "site_root"):
        assert kw in params, f"Fabric.login lost keyword {kw!r}"
        assert params[kw].kind is inspect.Parameter.KEYWORD_ONLY
    policy_fields = set(core.ReplicaPolicy.__dataclass_fields__)
    assert {"sites", "write_quorum", "queue_aware",
            "capacity_bytes", "eviction"} <= policy_fields
    ev_fields = set(core.EvictionSpec.__dataclass_fields__)
    assert {"capacity", "high_watermark", "low_watermark", "policy",
            "scan_period_s"} <= ev_fields
    stats_fields = set(core.CacheStats.__dataclass_fields__)
    assert {"hits", "misses", "fills", "fills_from",
            "bytes_resident"} <= stats_fields
    site_fields = set(core.SiteSpec.__dataclass_fields__)
    assert {"name", "root", "nic_budget"} <= site_fields
    link_fields = set(core.LinkSpec.__dataclass_fields__)
    assert {"a", "b", "latency_s", "link"} <= link_fields
    mount_fields = set(core.MountSpec.__dataclass_fields__)
    assert {"prefix", "localized"} <= mount_fields
    spec_fields = set(core.FabricSpec.__dataclass_fields__)
    assert {"sites", "links", "link", "maintenance"} <= spec_fields
    m_fields = set(core.MaintenanceSpec.__dataclass_fields__)
    assert {"resync_period_s", "repair_period_s", "lease_period_s",
            "reconcile_period_s", "retry", "lock_lease_s"} <= m_fields
    r_fields = set(core.MaintenanceReport.__dataclass_fields__)
    assert {"tasks_run", "retries", "dead_lettered", "lock_conflicts",
            "repairs", "double_repairs", "evictions", "conflicts",
            "bytes_third_party", "bytes_client_mediated"} <= r_fields
    assert "write_lease" in policy_fields
    assert "bulk" in policy_fields
    assert "bulk" in spec_fields
    b_fields = set(core.BulkSpec.__dataclass_fields__)
    assert {"min_streams", "max_streams", "probe_bytes", "adapt",
            "third_party", "grow_step", "backoff", "improve_threshold",
            "degrade_threshold"} <= b_fields
    lease_fields = set(core.WriteLeaseSpec.__dataclass_fields__)
    assert {"ttl_s"} <= lease_fields
    c_fields = set(core.ConflictRecord.__dataclass_fields__)
    assert {"path", "seq", "owner", "ours_vts", "theirs_vts", "winner",
            "ours_data", "theirs_data", "detected_at"} <= c_fields
    for ev in (core.PartitionEvent, core.FlapEvent, core.HealEvent,
               core.CrashEvent):
        assert "at_s" in ev.__dataclass_fields__, f"{ev.__name__} lost at_s"
    plan_params = inspect.signature(core.FaultPlan.chaos).parameters
    for kw in ("seed", "horizon_s", "events", "crash_sites"):
        assert kw in plan_params, f"FaultPlan.chaos lost keyword {kw!r}"


def test_deprecated_shim_still_exported():
    """ussh_login stays importable until a major version drops it."""
    assert callable(core.ussh_login)
