"""Deterministic fault-injection harness: plan expansion, anchored
outage windows, lazy pumping, scheduler integration, crash hooks, seeded
chaos determinism, and the two trace-identity witnesses (FaultPlan-driven
choreography == hand-rolled calls; unarmed == armed-empty).
"""
import pytest

from repro.core import (
    CrashEvent, Endpoint, Fabric, FabricSpec, FaultInjector, FaultPlan,
    FlapEvent, HealEvent, LinkModel, MaintenanceSpec, Network,
    PartitionEvent, ReplicaPolicy,
)

HOME_LATENCY = 0.060


def net2():
    net = Network(link=LinkModel(latency_s=HOME_LATENCY))
    Endpoint("site", net)
    Endpoint("home", net)
    return net


# ---- plan expansion ---------------------------------------------------------

def test_actions_sort_by_time_then_declaration_order():
    plan = FaultPlan(events=(
        HealEvent(at_s=5.0, a="a", b="b"),
        PartitionEvent(at_s=1.0, a="a", b="b", duration_s=2.0),
        CrashEvent(at_s=5.0, site="home"),          # ties with the heal
    ))
    acts = plan.actions()
    assert [(t, kind) for t, _i, kind, _a in acts] == [
        (1.0, "partition"), (5.0, "heal"), (5.0, "crash")]
    # the tie resolves in declaration order: heal (decl 0) before crash
    assert acts[1][2] == "heal" and acts[2][2] == "crash"


def test_flap_expands_to_anchored_windows():
    plan = FaultPlan(events=(
        FlapEvent(at_s=10.0, a="a", b="b", down_s=2.0, period_s=8.0,
                  count=3),))
    acts = plan.actions()
    assert [t for t, *_ in acts] == [10.0, 18.0, 26.0]
    assert all(kind == "partition" and args == ("a", "b", 2.0)
               for _t, _i, kind, args in acts)


@pytest.mark.parametrize("bad", [
    lambda: PartitionEvent(at_s=-1.0, a="a", b="b"),
    lambda: PartitionEvent(at_s=0.0, a="a", b="b", duration_s=0.0),
    lambda: FlapEvent(at_s=0.0, a="a", b="b", down_s=0.0, period_s=1.0),
    lambda: FlapEvent(at_s=0.0, a="a", b="b", down_s=1.0, period_s=0.0),
    lambda: FlapEvent(at_s=0.0, a="a", b="b", down_s=1.0, period_s=1.0,
                      count=0),
    lambda: HealEvent(at_s=-0.5, a="a", b="b"),
    lambda: CrashEvent(at_s=-2.0, site="home"),
])
def test_event_validation(bad):
    with pytest.raises(ValueError):
        bad()


def test_plan_rejects_non_events():
    with pytest.raises(TypeError):
        FaultPlan(events=(("partition", 1.0),))


# ---- injector semantics -----------------------------------------------------

def test_windows_anchor_at_event_time_not_pump_time():
    """The clock may jump past an event before the plan is pumped; the
    outage window must still open at the declared instant — a window the
    clock has fully passed is skipped, not stretched."""
    net = net2()
    net.arm_faults(FaultInjector(net, FaultPlan(events=(
        PartitionEvent(at_s=1.0, a="site", b="home", duration_s=2.0),
        PartitionEvent(at_s=10.0, a="site", b="home", duration_s=2.0),
    ))))
    # one coarse jump to t=5 crosses the whole first window [1, 3)
    net.advance(5.0)
    assert not net.is_partitioned("site", "home")   # lapsed, never stretched
    net.advance(6.0)                                # t=11: inside [10, 12)
    assert net.is_partitioned("site", "home")
    net.advance(1.0)                                # t=12: window closed
    assert not net.is_partitioned("site", "home")


def test_heal_event_cancels_an_unbounded_partition():
    net = net2()
    inj = FaultInjector(net, FaultPlan(events=(
        PartitionEvent(at_s=0.0, a="site", b="home"),      # until healed
        HealEvent(at_s=30.0, a="site", b="home"),
    )))
    net.arm_faults(inj)
    assert net.is_partitioned("site", "home")
    net.advance(29.0)
    assert net.is_partitioned("site", "home")
    net.advance(2.0)
    assert not net.is_partitioned("site", "home")
    assert inj.done() and inj.fired == 2


def test_transfer_pumps_due_events():
    """A transfer issued after an event's time sees the outage without
    anyone calling advance() or is_partitioned() first."""
    from repro.core import DisconnectedError
    net = net2()
    net.arm_faults(FaultInjector(net, FaultPlan(events=(
        PartitionEvent(at_s=0.0, a="site", b="home", duration_s=5.0),))))
    with pytest.raises(DisconnectedError):
        net.rpc("site", "home", "probe")


# ---- fabric integration -----------------------------------------------------

def mfab(tmp_path, maintenance=None):
    spec = FabricSpec.star(str(tmp_path / "h"), str(tmp_path / "s"),
                           replica_latencies={"r1": 0.005},
                           link=LinkModel(latency_s=HOME_LATENCY))
    if maintenance is not None:
        import dataclasses
        spec = dataclasses.replace(spec, maintenance=maintenance)
    return Fabric(spec)


def test_crash_event_drops_server_session_state(tmp_path):
    fab = mfab(tmp_path)
    s = fab.login("sci")
    inj = fab.arm_faults(FaultPlan(events=(
        CrashEvent(at_s=s.network.clock + 1.0, site="home"),)))
    s.network.advance(2.0)
    assert inj.crashes == 1
    from repro.core import AuthError
    with pytest.raises(AuthError):
        s.server.store.get(s.token, "home/x")       # token gone
    s.remount()                                     # the crontab restart
    with s.client.open("home/d/a.bin", "w") as f:
        f.write(b"recovered")
    s.client.pump()
    assert s.server.store.get(s.token, "home/d/a.bin")[0] == b"recovered"


def test_scheduler_walks_the_clock_through_fault_times(tmp_path):
    """run_until must tick *at* fault instants, so windows open and close
    on schedule even when no task is due there."""
    fab = mfab(tmp_path, maintenance=MaintenanceSpec())
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    t0 = s.network.clock
    inj = fab.arm_faults(FaultPlan(events=(
        PartitionEvent(at_s=t0 + 2.0, a="home", b="r1", duration_s=3.0),)))
    assert s.scheduler.next_event() <= t0 + 2.0
    s.scheduler.run_until(t0 + 2.5, advance_to_stop=True)
    assert s.network.is_partitioned("home", "r1")
    s.scheduler.run_until(t0 + 6.0)
    assert not s.network.is_partitioned("home", "r1")
    assert inj.done()


# ---- seeded chaos -----------------------------------------------------------

def test_chaos_is_a_pure_function_of_the_seed():
    pairs = [("site", "home"), ("home", "r1")]
    a = FaultPlan.chaos(pairs, seed=7, horizon_s=60.0, events=6,
                        crash_sites=("home",))
    b = FaultPlan.chaos(pairs, seed=7, horizon_s=60.0, events=6,
                        crash_sites=("home",))
    c = FaultPlan.chaos(pairs, seed=8, horizon_s=60.0, events=6,
                        crash_sites=("home",))
    assert a == b
    assert a != c
    assert all(isinstance(e, (PartitionEvent, CrashEvent))
               for e in a.events)
    for e in a.events:
        if isinstance(e, PartitionEvent):
            assert 0.0 <= e.at_s < 60.0
            assert 0.5 <= e.duration_s <= 5.0


def test_chaos_validation():
    with pytest.raises(ValueError):
        FaultPlan.chaos([], seed=1, horizon_s=10.0)
    with pytest.raises(ValueError):
        FaultPlan.chaos([("a", "b")], seed=1, horizon_s=0.0)
    with pytest.raises(ValueError):
        FaultPlan.chaos([("a", "b")], seed=1, horizon_s=10.0,
                        min_down_s=3.0, max_down_s=1.0)


# ---- trace identity witnesses ----------------------------------------------

def _drive(fab):
    s = fab.login("bench", replicas=ReplicaPolicy(sites=("r1",)))
    with s.client.open("home/d/t.bin", "w") as f:
        f.write(b"T" * 300_000)
    s.client.pump()
    s.network.advance(10.0)              # crosses any armed window
    s.client.pump()
    with s.client.open("home/d/t.bin") as f:
        f.read()
    return s.network.trace


def test_faultplan_choreography_matches_hand_rolled_calls(tmp_path):
    """The same outage declared via FaultPlan or issued as a direct
    ``network.partition(...)`` call at the same instant yields the same
    wire trace — the harness adds scheduling, not behavior."""
    fab_hand = mfab(tmp_path / "hand")
    s_pre = fab_hand.network.clock
    fab_hand.network.partition("home", "r1", duration=8.0)
    assert fab_hand.network.clock == s_pre
    hand = _drive(fab_hand)

    fab_plan = mfab(tmp_path / "plan")
    fab_plan.arm_faults(FaultPlan(events=(
        PartitionEvent(at_s=fab_plan.network.clock, a="home", b="r1",
                       duration_s=8.0),)))
    planned = _drive(fab_plan)
    assert hand == planned


def test_armed_empty_plan_leaves_the_trace_bit_identical(tmp_path):
    unarmed = _drive(mfab(tmp_path / "u"))
    fab = mfab(tmp_path / "a")
    fab.arm_faults(FaultPlan())
    assert _drive(fab) == unarmed
