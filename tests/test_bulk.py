"""Bulk-transfer plane: adaptive stream grants, AIMD waves, third-party
replica→replica repair, and the gating identities (spec-unset and
fixed-width plans are bit-identical to the legacy engine)."""
import dataclasses

import pytest

from repro.core import (
    BulkSpec, BulkTransfer, Endpoint, Fabric, FabricSpec, KB, LinkModel,
    MaintenanceSpec, MB, Network, ReplicaPolicy, RetryPolicy,
    StripedTransfer, ensure_channel_width, grant_streams,
)

#: Fixed-width, no-third-party spec: provably identical to the legacy
#: 12-stream constant (the satellite-1 identity witness).
NEUTRAL = BulkSpec(min_streams=1, max_streams=12, adapt=False,
                   third_party=False)


def pair_net(width=12, names=("a", "b")):
    net = Network(channels_per_pair=width)
    for nm in names:
        Endpoint(nm, net)
    return net


# ---- BulkSpec / grant_streams ----------------------------------------------

def test_bulkspec_validates():
    for bad in (dict(min_streams=0), dict(min_streams=8, max_streams=4),
                dict(probe_bytes=0), dict(grow_step=0), dict(backoff=1.0),
                dict(backoff=0.0), dict(improve_threshold=-0.1)):
        with pytest.raises(ValueError):
            BulkSpec(**bad)


def test_grant_streams_fills_the_bdp():
    net = pair_net()
    # default link: 3.75 GB/s over 80 MB/s window-limited streams
    # => exactly 48 streams fill the path
    assert grant_streams(net, "a", "b", 1024 * MB, BulkSpec()) == 48
    # spec window clamps the fill count
    assert grant_streams(net, "a", "b", 1024 * MB,
                         BulkSpec(max_streams=16)) == 16
    # payload clamp: one stream per 64 KB, tiny payloads stay single
    assert grant_streams(net, "a", "b", 60 * KB, BulkSpec()) == 1
    assert grant_streams(net, "a", "b", 256 * KB, BulkSpec()) == 4


def test_grant_streams_respects_nic_budget():
    net = pair_net()
    net.set_nic_budget("a", 160 * MB)   # 2 streams' worth of NIC
    assert grant_streams(net, "a", "b", 1024 * MB, BulkSpec()) == 2
    # fixed-width mode ignores the derivation entirely (identity mode)
    assert grant_streams(net, "a", "b", 1024 * MB, NEUTRAL) == 12


# ---- channels_per_pair raised after construction (regression) --------------

def test_channels_raised_midrun_pads_idle_columns():
    """Raising the channel pool after construction pads idle columns
    (transport.py `_ensure_chan_width`): the padded net must behave
    exactly like one constructed wide, and the new columns must be
    usable immediately."""
    grown, wide = pair_net(2), pair_net(4)
    for net in (grown, wide):
        for _ in range(2):
            net.transfer("a", "b", "blk", 4 * MB)
    ensure_channel_width(grown, 4)          # the mid-run raise
    assert int(grown.channels_per_pair) == 4
    reqs = [("a", "b", "blk", 4 * MB, 4, False, 0.0)] * 4
    for net in (grown, wide):
        net.wait_batch(net.transfer_batch(reqs))
        net.drain()
    assert grown.trace == wide.trace
    # the padded columns are real channels: the batch lands on them
    # instead of queueing behind the two originally-constructed ones
    post_raise_channels = {row[4] for row in grown.trace[2:]}
    assert {2, 3} <= post_raise_channels


def test_ensure_channel_width_never_lowers():
    net = pair_net(12)
    ensure_channel_width(net, 4)
    assert int(net.channels_per_pair) == 12


# ---- the AIMD executor -----------------------------------------------------

def test_adaptive_beats_fixed_width_on_high_bdp_link():
    fixed_net, adapt_net = pair_net(), pair_net()
    nbytes = 64 * MB
    fixed = BulkTransfer(fixed_net, BulkSpec(
        min_streams=12, max_streams=12, adapt=False,
        third_party=False)).push("a", "b", nbytes)
    adaptive = BulkTransfer(adapt_net, BulkSpec(
        max_streams=64, probe_bytes=4 * MB)).push("a", "b", nbytes)
    assert fixed.widths == (12,)
    assert adaptive.widths[0] == 48         # seeded at the BDP grant
    assert adaptive.elapsed_s < fixed.elapsed_s
    assert adaptive.throughput_bps > fixed.throughput_bps


def test_aimd_grows_then_backs_off_under_nic_congestion():
    net = pair_net(names=("a", "b", "c"))
    net.set_nic_budget("a", 200 * MB)       # 3 streams' worth
    spec = BulkSpec(min_streams=1, max_streams=8, probe_bytes=1 * MB,
                    grow_step=2)
    bt = BulkTransfer(net, spec)

    def congest(idx, width, chunk, dt):
        if idx == 1:
            # a fat competing flow lands on a's NIC between waves: the
            # next wave's completion stretches behind its backlog
            net.transfer("a", "c", "competing", 200 * MB)

    r = bt.push("a", "b", 48 * MB, wave_cb=congest)
    assert r.widths[0] == 3                 # NIC-clamped grant
    assert max(r.widths) > 3                # additive increase happened
    assert any(b < a for a, b in zip(r.widths, r.widths[1:])), \
        f"no multiplicative backoff in {r.widths}"
    assert r.nbytes == 48 * MB


def test_push_zero_and_send_roundtrip():
    net = pair_net()
    bt = BulkTransfer(net)
    assert bt.push("a", "b", 0).waves == 0
    r = bt.send("a", "b", b"x" * (2 * MB))
    assert r.nbytes == 2 * MB and r.elapsed_s > 0


# ---- striping width from the granted budget (satellite 1) ------------------

def test_fixed_width_striping_is_bit_identical():
    """A fixed-width spec (adapt off, max_streams=12) must produce the
    exact trace of the legacy constant — including with NIC budgets
    armed, which the fixed mode must not consult."""
    legacy_net, spec_net = pair_net(), pair_net()
    for net in (legacy_net, spec_net):
        net.set_nic_budget("a", 300 * MB)
    legacy = StripedTransfer(legacy_net)
    fixed = StripedTransfer(spec_net, spec=NEUTRAL)
    for size in (0, 1 * KB, 64 * KB, 64 * KB + 1, 1 * MB, 10 * MB + 7):
        payload = b"z" * size
        legacy.send("a", "b", payload)
        fixed.send("a", "b", payload)
    assert legacy_net.trace == spec_net.trace


def test_adaptive_striping_widens_past_the_constant():
    net = pair_net()
    st = StripedTransfer(net, spec=BulkSpec(max_streams=64))
    group = st.begin("a", "b", b"z" * (16 * MB))
    assert group.plan.n_streams == 48       # BDP grant, not MAX_STRIPES
    assert int(net.channels_per_pair) >= 48  # pool raised to carry it


# ---- the replica fabric: third-party movement ------------------------------

def bulk_login(tmp_path, bulk, tag, maintenance=None):
    spec = FabricSpec.star(str(tmp_path / f"home-{tag}"),
                           str(tmp_path / f"site-{tag}"),
                           replica_latencies={"r1": 0.005, "r2": 0.015},
                           link=LinkModel(latency_s=0.060))
    if maintenance is not None:
        spec = dataclasses.replace(spec, maintenance=maintenance)
    fab = Fabric(spec)
    return fab.login("sci", replicas=ReplicaPolicy(sites=("r1", "r2"),
                                                   bulk=bulk))


TP = BulkSpec(min_streams=1, max_streams=12, adapt=False,
              third_party=True)
PATH = "home/data/ckpt.bin"


def make_r2_stale(s, payload=b"B" * (1 * MB)):
    """Seed both replicas, then land a new home version that only r1
    sees (r2 partitioned during the resync) — r2 ends lagging, r1 is a
    fresh third-party source."""
    net = s.client.network
    s.server.store.put(s.token, PATH, b"A" * len(payload))
    s.replicas.resync()
    s.server.store.put(s.token, PATH, payload)
    # cut r2 from BOTH sources: with only home<->r2 down, a third-party
    # fabric would route the repair around the partition via r1
    net.partition("home", "r2")
    net.partition("r1", "r2")
    s.replicas.resync()
    net.heal("home", "r2")
    net.heal("r1", "r2")
    assert PATH in s.replicas.replicas["r2"].lagging
    return payload


def test_repair_pulls_replica_to_replica(tmp_path):
    s = bulk_login(tmp_path, TP, "tp")
    net = s.client.network
    payload = make_r2_stale(s)
    before = net.per_pair_bytes.get(("r1", "r2"), 0)
    pulls0 = s.replicas.third_party_pulls
    pending = s.replicas.begin_repair_path(PATH)
    assert [p.src for p in pending] == ["r1"]     # nearer than home
    for p in pending:
        net.wait(p.ack)
        s.replicas.complete_apply(p)
    assert net.per_pair_bytes[("r1", "r2")] - before >= len(payload)
    assert s.replicas.third_party_pulls == pulls0 + 1
    assert net.bytes_third_party >= len(payload)
    st = s.replicas.replicas["r2"]
    assert st.store.get(st.token, PATH)[0] == payload
    assert PATH not in st.lagging


def test_third_party_selection_skips_partitioned_sources(tmp_path):
    s = bulk_login(tmp_path, TP, "tpskip")
    net = s.client.network
    make_r2_stale(s)
    net.partition("r1", "r2")                 # third-party path down
    src = s.replicas.third_party_source(
        "r2", PATH, s.server.store.stat(s.token, PATH).version, 1 * MB)
    assert src == "home"                      # inf-cost candidate skipped
    net.heal("r1", "r2")


def test_fallback_to_mediated_when_source_partitions_mid_pull(tmp_path):
    s = bulk_login(tmp_path, TP, "tpfall")
    net = s.client.network
    payload = make_r2_stale(s)
    ver = s.server.store.stat(s.token, PATH).version
    net.partition("r1", "r2")
    p = s.replicas.begin_apply("r2", PATH, payload, ver,
                               src="r1", fallback_src="home")
    assert p is not None and p.src == "home"
    assert s.replicas.third_party_fallbacks == 1
    net.wait(p.ack)
    s.replicas.complete_apply(p)
    st = s.replicas.replicas["r2"]
    assert st.store.get(st.token, PATH)[0] == payload
    # both paths down: the apply defers exactly like the legacy fabric
    net.partition("home", "r2")
    p2 = s.replicas.begin_apply("r2", PATH, payload, ver + 1,
                                src="r1", fallback_src="home")
    assert p2 is None
    assert PATH in s.replicas.replicas["r2"].lagging
    net.heal("home", "r2")
    net.heal("r1", "r2")


def test_read_repair_provenance_and_offload(tmp_path):
    mediated = bulk_login(tmp_path, None, "cm")
    third = bulk_login(tmp_path, TP, "tp3")
    for s in (mediated, third):
        payload = make_r2_stale(s)
        net = s.client.network
        cm0, tp0 = net.bytes_client_mediated, net.bytes_third_party
        with s.client.open(PATH) as f:
            assert f.read() == payload
        net.drain()
        if s is mediated:
            # legacy: the reading client pushes the repair bytes
            assert net.bytes_client_mediated - cm0 >= len(payload)
            assert s.replicas.third_party_pulls == 0
        else:
            # bulk plane: the repair pulls from a storage endpoint
            assert net.bytes_client_mediated == cm0
            assert net.bytes_third_party - tp0 >= len(payload)
            assert s.replicas.third_party_pulls >= 1
        assert PATH not in s.replicas.replicas["r2"].lagging


# ---- scheduler integration: retry ladder, no dead-letter on first failure --

def test_scheduled_resync_retries_without_dead_letter(tmp_path):
    s = bulk_login(tmp_path, TP, "sched", maintenance=MaintenanceSpec(
        resync_period_s=5.0, repair_period_s=2.0,
        lease_period_s=1000.0, reconcile_period_s=1000.0,
        retry=RetryPolicy(max_retries=3)))
    net = s.client.network
    s.server.store.put(s.token, PATH, b"A" * MB)
    net.partition("site", "home")
    s.scheduler.run_until(net.clock + 5.5)
    rep = s.maintenance_report()
    key = next(k for k in rep.tasks if k.startswith("resync:"))
    assert rep.tasks[key]["failures"] == 1
    assert rep.tasks[key]["attempt"] == 1     # on the ladder, not dead
    assert rep.dead_lettered == 0
    net.heal("site", "home")
    s.scheduler.run_until(net.clock + 10.0)
    rep2 = s.maintenance_report()
    assert rep2.dead_lettered == 0
    assert rep2.tasks[key]["attempt"] == 0    # episode closed on success
    assert s.replicas.catalog.version_at(PATH, "r1") is not None


# ---- the zero-cost identity ------------------------------------------------

def _workload_trace(tmp_path, bulk, tag):
    s = bulk_login(tmp_path, bulk, tag)
    net = s.client.network
    payload = make_r2_stale(s)
    with s.client.open(PATH) as f:
        assert f.read() == payload
    for p in s.replicas.begin_repair_path(PATH):
        net.wait(p.ack)
        s.replicas.complete_apply(p)
    with s.client.open("home/data/out.bin", "w") as f:
        f.write(b"C" * (2 * MB))
    s.client.sync()
    net.drain()
    return list(net.trace)


def test_neutral_spec_trace_is_bit_identical_to_unset(tmp_path):
    """The full gating identity: a fixed-width, third-party-off spec
    takes exactly the legacy code paths — reads, read repair, repair
    drain, and flusher fan-out produce the same trace bit-for-bit."""
    assert _workload_trace(tmp_path, None, "base") == \
        _workload_trace(tmp_path, NEUTRAL, "neutral")
