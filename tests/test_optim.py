"""Optimizer stack: AdamW reference check, int8 state codec, EF compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.config.base import OptimConfig
from repro.optim import (
    init_state, adamw_update, clip_by_global_norm, q8_encode, q8_decode,
    init_error, compress_decompress, lr_at,
)


def test_adamw_matches_manual_reference():
    cfg = OptimConfig(lr=0.1, weight_decay=0.0, b1=0.9, b2=0.99, eps=1e-8)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]])}
    g = {"w": jnp.asarray([[0.1, 0.2], [-0.3, 0.4]])}
    st_ = init_state(p, cfg)
    new_p, st2 = adamw_update(g, st_, p, jnp.asarray(0.1), cfg)
    # manual first-step adam: m_hat = g, v_hat = g^2 -> step = g/(|g|+eps)
    expect = p["w"] - 0.1 * (g["w"] / (jnp.abs(g["w"]) + 1e-8))
    np.testing.assert_allclose(np.asarray(new_p["w"]), np.asarray(expect),
                               rtol=1e-5)
    assert int(st2["count"]) == 1


def test_weight_decay_applies_to_matrices_only():
    cfg = OptimConfig(lr=0.1, weight_decay=0.5)
    p = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    g = {"w": jnp.zeros((2, 2)), "b": jnp.zeros((2,))}
    st_ = init_state(p, cfg)
    new_p, _ = adamw_update(g, st_, p, jnp.asarray(0.1), cfg)
    assert float(new_p["w"][0, 0]) < 1.0      # decayed
    assert float(new_p["b"][0]) == 1.0        # not decayed


@given(st.integers(min_value=1, max_value=300),
       st.integers(min_value=8, max_value=128))
@settings(max_examples=30, deadline=None)
def test_q8_roundtrip_error_bound(n, block):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,)) * 3.0
    q, s = q8_encode(x, block)
    out = q8_decode(q, s, block)
    # blockwise max-abs scaling: error <= scale/2 = max|block| / 254
    assert out.shape == x.shape
    err = np.abs(np.asarray(out - x))
    bound = np.asarray(jnp.repeat(s, block)[:n]) * 0.5 + 1e-7
    assert np.all(err <= bound + 1e-6)


def test_int8_adamw_tracks_fp32_adamw():
    """Blockwise-int8 moments stay close to fp32 moments over steps."""
    key = jax.random.PRNGKey(0)
    p32 = {"w": jax.random.normal(key, (64, 64))}
    p8 = jax.tree.map(jnp.copy, p32)
    cfg32 = OptimConfig(lr=1e-2, weight_decay=0.0)
    cfg8 = OptimConfig(lr=1e-2, weight_decay=0.0, state_dtype="int8",
                       int8_block=32)
    s32, s8 = init_state(p32, cfg32), init_state(p8, cfg8)
    for i in range(10):
        g = {"w": jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        p32, s32 = adamw_update(g, s32, p32, jnp.asarray(1e-2), cfg32)
        p8, s8 = adamw_update(g, s8, p8, jnp.asarray(1e-2), cfg8)
    diff = float(jnp.max(jnp.abs(p32["w"] - p8["w"])))
    scale = float(jnp.max(jnp.abs(p32["w"])))
    # int8 moments quantize per 32-elem block: parameters must stay within
    # a fraction of the fp32 trajectory (updates are lr-bounded), not match
    assert diff / scale < 0.25, diff / scale
    # and the updates must point the same way on average
    d32 = p32["w"] - jax.random.normal(key, (64, 64))
    d8 = p8["w"] - jax.random.normal(key, (64, 64))
    cos = float(jnp.sum(d32 * d8)
                / (jnp.linalg.norm(d32) * jnp.linalg.norm(d8)))
    assert cos > 0.98, cos


def test_grad_clip_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                         for x in jax.tree.leaves(clipped)))
    assert abs(float(total) - 1.0) < 1e-5
    assert float(gn) > 1.0


def test_error_feedback_preserves_signal():
    """EF compression: accumulated compressed updates converge to the
    accumulated true gradient (error is fed back, not lost)."""
    key = jax.random.PRNGKey(1)
    g_true = {"w": jax.random.normal(key, (256,))}
    err = init_error(g_true)
    acc_comp = jnp.zeros((256,))
    for _ in range(50):
        deq, err = compress_decompress(g_true, err)
        acc_comp = acc_comp + deq["w"]
    acc_true = g_true["w"] * 50
    rel = float(jnp.linalg.norm(acc_comp - acc_true)
                / jnp.linalg.norm(acc_true))
    assert rel < 0.01, rel


def test_lr_schedule_shape():
    cfg = OptimConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(lr_at(0, cfg)) == 0.0
    assert abs(float(lr_at(10, cfg)) - 1.0) < 1e-6
    assert float(lr_at(60, cfg)) < 1.0
    assert float(lr_at(110, cfg)) <= 0.2   # floor*lr + epsilon
