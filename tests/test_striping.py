"""Striped-transfer engine: plan properties + byte-exact reassembly."""
import pytest
from _propcheck import given, settings, strategies as st

from repro.core.striping import (
    plan_stripes, reassemble, StripedTransfer, STRIPE_THRESHOLD, MIN_BLOCK,
    MAX_STRIPES,
)
from repro.core.transport import Network, Endpoint


@given(st.integers(min_value=0, max_value=256 * 1024 * 1024))
@settings(max_examples=300, deadline=None)
def test_plan_covers_every_byte_exactly_once(nbytes):
    plan = plan_stripes(nbytes)
    assert plan.total == nbytes
    covered = 0
    expect_off = 0
    for off, ln in plan.stripes:
        assert off == expect_off          # contiguous, ordered
        assert ln > 0 or nbytes == 0
        covered += ln
        expect_off = off + ln
    assert covered == nbytes


@given(st.integers(min_value=1, max_value=64 * 1024 * 1024))
@settings(max_examples=200, deadline=None)
def test_plan_respects_stripe_count_and_block_size(nbytes):
    plan = plan_stripes(nbytes)
    if nbytes <= STRIPE_THRESHOLD:
        assert plan.n_streams <= 1
    else:
        assert 1 <= plan.n_streams <= MAX_STRIPES
        # every stripe except possibly the last is >= MIN_BLOCK
        for off, ln in plan.stripes[:-1]:
            assert ln >= MIN_BLOCK


@given(st.binary(min_size=0, max_size=1 * 1024 * 1024))
@settings(max_examples=50, deadline=None)
def test_reassemble_roundtrip(payload):
    plan = plan_stripes(len(payload))
    parts = [payload[o:o + l] for o, l in plan.stripes]
    assert reassemble(plan, parts) == payload


@given(st.integers(min_value=0, max_value=512 * 1024 * 1024),
       st.integers(min_value=1, max_value=MAX_STRIPES))
@settings(max_examples=200, deadline=None)
def test_plan_invariants_under_any_stripe_budget(nbytes, max_stripes):
    """plan_stripes invariants for every (size, stripe budget):
    stripes cover [0, total) exactly once with no overlap, every block is
    >= MIN_BLOCK except possibly the tail, and n_streams <= budget."""
    plan = plan_stripes(nbytes, max_stripes=max_stripes)
    assert plan.total == nbytes
    assert plan.n_streams <= max(max_stripes, 1) <= MAX_STRIPES
    expect_off = 0
    for off, ln in plan.stripes:
        assert off == expect_off           # contiguous => no gap/overlap
        expect_off = off + ln
    assert expect_off == nbytes            # covers [0, total) exactly once
    for off, ln in plan.stripes[:-1]:
        assert ln >= MIN_BLOCK or nbytes <= STRIPE_THRESHOLD
    if nbytes > STRIPE_THRESHOLD and plan.stripes:
        _, tail = plan.stripes[-1]
        assert tail > 0


def test_striping_speedup_on_fat_link():
    """12 stripes must beat 1 stream on a window-limited WAN (paper §3.3)."""
    net = Network()
    Endpoint("a", net)
    Endpoint("b", net)
    xfer = StripedTransfer(net)
    payload = b"x" * (64 * 1024 * 1024)
    t0 = net.clock
    xfer.send("a", "b", payload, max_stripes=1)
    t_single = net.clock - t0
    t0 = net.clock
    xfer.send("a", "b", payload)
    t_striped = net.clock - t0
    assert t_striped < t_single / 6    # ~12x minus latency
