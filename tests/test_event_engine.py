"""Batched discrete-event engine vs the scalar transport path.

The contract under test: ``transfer_batch``/``estimate_batch`` are
*optimizations*, never model changes.  Any op list replayed through
batches of any shape must leave the network bit-identical to the scalar
replay — same trace, same clock after drain, same NIC backlogs, same
accounting — on every gated topology (plain star, replicated links,
quorum ack chains, NIC-budgeted).  Plus the event-queue invariant: the
heap pops completions in nondecreasing order.
"""
import heapq

import pytest

from _propcheck import given, settings, strategies as st
from repro.core import (
    DisconnectedError, LinkModel, MB, Network,
)

NAMES = ("alpha", "beta", "gamma", "delta")


def _mk_net(topo: str) -> Network:
    net = Network(link=LinkModel(latency_s=0.050), channels_per_pair=3)
    if topo == "replicated":
        # near / far replica links, the fig_replica_routing shape
        net.set_link("alpha", "gamma", LinkModel(latency_s=0.005))
        net.set_link("alpha", "delta", LinkModel(latency_s=0.015))
    elif topo == "nic":
        net.set_nic_budget("beta", 50 * MB)
        net.set_nic_budget("gamma", 20 * MB)
    return net


def _norm_ops(raw_ops):
    """Map drawn (s, d, nbytes) rows onto valid distinct-endpoint ops."""
    ops = []
    for s, d, nb in raw_ops:
        src = NAMES[s % 4]
        dst = NAMES[(s + 1 + (d % 3)) % 4]
        ops.append((src, dst, nb))
    return ops


def _chunks(seq, size):
    for i in range(0, len(seq), size):
        yield seq[i:i + size]


def _unique_pair_chunks(ops):
    """Greedy maximal runs of distinct pairs — forces the vectorized
    batch path (a duplicate pair would fall back to sequential)."""
    run, seen = [], set()
    for op in ops:
        key = (min(op[0], op[1]), max(op[0], op[1]))
        if key in seen:
            yield run
            run, seen = [], set()
        run.append(op)
        seen.add(key)
    if run:
        yield run


def _run_scalar(net, ops):
    for src, dst, nb in ops:
        net.transfer(src, dst, "op", nb)
    return net.drain()


def _assert_identical(net_a, net_b):
    assert net_a.trace == net_b.trace
    assert net_a.clock == net_b.clock
    assert net_a.bytes_sent == net_b.bytes_sent
    assert net_a.rpc_count == net_b.rpc_count
    assert dict(net_a.per_endpoint_rpcs) == dict(net_b.per_endpoint_rpcs)
    assert dict(net_a.per_endpoint_bytes) == dict(net_b.per_endpoint_bytes)
    assert dict(net_a.per_pair_rpcs) == dict(net_b.per_pair_rpcs)
    assert dict(net_a.per_pair_bytes) == dict(net_b.per_pair_bytes)
    assert dict(net_a._nic_free) == dict(net_b._nic_free)
    # busy_s folds float sums in different orders batch-vs-scalar;
    # everything above is exact, this one gets a ULP tolerance
    busy_a, busy_b = net_a.per_endpoint_busy_s, net_b.per_endpoint_busy_s
    assert set(busy_a) == set(busy_b)
    for ep, v in busy_a.items():
        assert busy_b[ep] == pytest.approx(v, abs=1e-9)


OPS = st.lists(
    st.tuples(st.integers(0, 3), st.integers(0, 3),
              st.integers(0, 256 * 1024)),
    min_size=0, max_size=40)


@pytest.mark.parametrize("topo", ["plain", "replicated", "nic"])
@settings(max_examples=10)
@given(OPS, st.integers(1, 7))
def test_batched_replay_identical(topo, raw_ops, chunk):
    """Fixed-size chunks (duplicate-pair heavy with 4 endpoints, so the
    sequential fallback is exercised) leave state identical to scalar."""
    ops = _norm_ops(raw_ops)
    net_s = _mk_net(topo)
    _run_scalar(net_s, ops)
    net_b = _mk_net(topo)
    for group in _chunks(ops, chunk):
        net_b.transfer_batch([(s, d, "op", nb) for s, d, nb in group])
    net_b.drain()
    _assert_identical(net_s, net_b)


@pytest.mark.parametrize("topo", ["plain", "replicated", "nic"])
@settings(max_examples=10)
@given(OPS)
def test_vectorized_path_identical(topo, raw_ops):
    """Unique-pair chunks take the fully vectorized path; still
    bit-identical to scalar."""
    ops = _norm_ops(raw_ops)
    net_s = _mk_net(topo)
    _run_scalar(net_s, ops)
    net_b = _mk_net(topo)
    for group in _unique_pair_chunks(ops):
        net_b.transfer_batch([(s, d, "op", nb) for s, d, nb in group])
    net_b.drain()
    _assert_identical(net_s, net_b)


@settings(max_examples=10)
@given(OPS)
def test_nic_conservation(raw_ops):
    """A budgeted NIC's backlog clock covers every byte it carried:
    backlog >= sum(bytes) / budget, scalar and batched agree exactly."""
    ops = _norm_ops(raw_ops)
    net = _mk_net("nic")
    for group in _chunks(ops, 5):
        net.transfer_batch([(s, d, "op", nb) for s, d, nb in group])
    net.drain()
    for ep, budget in net.nic_budgets.items():
        carried = sum(nb for s, d, nb in ops if ep in (s, d) and nb > 0)
        if carried:
            assert net._nic_free[ep] + 1e-9 >= carried / budget


@pytest.mark.parametrize("topo", ["plain", "replicated", "nic"])
@settings(max_examples=10)
@given(OPS, st.integers(1, 6))
def test_quorum_ack_chain_identical(topo, raw_ops, chunk):
    """Quorum-style ack chains (ack reserved with ``not_before`` at the
    data's completion) drain in the same order batched as scalar."""
    ops = _norm_ops(raw_ops)
    # same algorithm both ways: per group, all stores then all acks
    # (acks share the store's pair, so issue order IS the contract)
    net_s = _mk_net(topo)
    for group in _chunks(ops, chunk):
        datas = [net_s.transfer(s, d, "store", nb) for s, d, nb in group]
        for (s, d, _nb), t in zip(group, datas):
            net_s.transfer(d, s, "ack", 128, not_before=t.completion)
    order_s = sorted((t.completion, t.src, t.dst, t.start, t.channel)
                     for t in net_s.outstanding())
    net_s.drain()

    net_b = _mk_net(topo)
    for group in _chunks(ops, chunk):
        data = net_b.transfer_batch(
            [(s, d, "store", nb) for s, d, nb in group])
        net_b.transfer_batch(
            [(d, s, "ack", 128, 1, False, co)
             for (s, d, _nb), co in zip(group,
                                        data.completions.tolist())])
    order_b = sorted((t.completion, t.src, t.dst, t.start, t.channel)
                     for t in net_b.outstanding())
    net_b.drain()
    assert order_s == order_b
    _assert_identical(net_s, net_b)


@settings(max_examples=10)
@given(OPS, st.integers(1, 5))
def test_event_heap_pops_nondecreasing(raw_ops, chunk):
    """The event queue is a real heap: popping the pending set yields
    completions in nondecreasing order."""
    ops = _norm_ops(raw_ops)
    net = _mk_net("plain")
    for group in _chunks(ops, chunk):
        net.transfer_batch([(s, d, "op", nb) for s, d, nb in group])
    heap = list(net._event_heap)
    heapq.heapify(heap)
    last = float("-inf")
    while heap:
        completion, _seq, _item = heapq.heappop(heap)
        assert completion >= last
        last = completion


@pytest.mark.parametrize("topo", ["plain", "replicated", "nic"])
@settings(max_examples=10)
@given(OPS, st.integers(0, 256 * 1024), st.floats(0.0, 2.0))
def test_estimate_batch_matches_scalar(topo, raw_ops, nbytes, not_before):
    """estimate_batch is element-for-element float-identical to
    estimated_completion, including on a loaded network."""
    ops = _norm_ops(raw_ops)
    net = _mk_net(topo)
    for group in _chunks(ops, 4):
        net.transfer_batch([(s, d, "op", nb) for s, d, nb in group])
    srcs = [a for a in NAMES for b in NAMES if a != b]
    dsts = [b for a in NAMES for b in NAMES if a != b]
    got = net.estimate_batch(srcs, dsts, nbytes, not_before=not_before)
    for i, (s, d) in enumerate(zip(srcs, dsts)):
        assert got[i] == net.estimated_completion(
            s, d, nbytes, not_before=not_before)


def test_partitioned_batch_raises_like_scalar():
    """A batch touching a partitioned pair raises after applying exactly
    the prefix a sequential caller would have applied."""
    ops = [("alpha", "beta", 1000), ("alpha", "gamma", 2000),
           ("beta", "gamma", 3000), ("alpha", "delta", 500)]

    net_s = _mk_net("plain")
    net_s.partition("beta", "gamma")
    with pytest.raises(DisconnectedError):
        for src, dst, nb in ops:
            net_s.transfer(src, dst, "op", nb)

    net_b = _mk_net("plain")
    net_b.partition("beta", "gamma")
    with pytest.raises(DisconnectedError):
        net_b.transfer_batch([(s, d, "op", nb) for s, d, nb in ops])

    assert net_s.trace == net_b.trace
    assert net_s.bytes_sent == net_b.bytes_sent
    net_s.drain()
    net_b.drain()
    assert net_s.clock == net_b.clock


def test_caller_pair_ids_identical():
    """Caller-supplied pair_ids (intern_pairs) change nothing."""
    ops = [("alpha", "beta", 1000), ("alpha", "gamma", 2000),
           ("beta", "delta", 3000)]
    net_a = _mk_net("plain")
    net_a.transfer_batch([(s, d, "op", nb) for s, d, nb in ops])
    net_a.drain()
    net_b = _mk_net("plain")
    pids = net_b.intern_pairs([s for s, d, nb in ops],
                              [d for s, d, nb in ops])
    net_b.transfer_batch([(s, d, "op", nb) for s, d, nb in ops],
                         pair_ids=pids)
    net_b.drain()
    _assert_identical(net_a, net_b)
