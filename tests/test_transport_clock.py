"""Channel-clock properties: the tentpole invariants of the transport.

Three property families (via the ``_propcheck`` hypothesis shim):

  * per-channel monotonicity — a channel is a serial resource: each
    reservation starts at or after the previous completion on it;
  * overlap bound — draining a batch of transfers can never take longer
    on the virtual clock than running them back-to-back (overlapped
    elapsed <= serial sum);
  * determinism — the same op sequence replays to a bit-identical
    ``Network.trace`` and final clock (the reproducibility contract every
    benchmark figure rests on).

Plus the striping acceptance check: a striped send's elapsed time equals
the max over its stripe channels, not the sum.
"""
import random

from _propcheck import given, settings, strategies as st

from repro.core.striping import StripedTransfer, MAX_STRIPES
from repro.core.transport import Endpoint, LinkModel, Network

N_EPS = 4


def _mknet(latency: float = 0.010) -> Network:
    net = Network(link=LinkModel(latency_s=latency))
    for i in range(N_EPS):
        Endpoint(f"e{i}", net)
    return net


def _run_ops(net, ops):
    """Issue a mixed batch: some transfers waited inline, the rest
    drained at the end (the fan-out shape)."""
    issued = []
    for si, di, nbytes, wait_now in ops:
        src, dst = f"e{si % N_EPS}", f"e{di % N_EPS}"
        if src == dst:
            continue
        t = net.transfer(src, dst, "op", nbytes)
        issued.append(t)
        if wait_now:
            net.wait(t)
    net.wait_all(issued)
    return issued


OPS = st.lists(
    st.tuples(st.integers(min_value=0, max_value=N_EPS - 1),
              st.integers(min_value=0, max_value=N_EPS - 1),
              st.integers(min_value=0, max_value=4 * 1024 * 1024),
              st.booleans()),
    min_size=1, max_size=48)


@given(OPS)
@settings(max_examples=50, deadline=None)
def test_completion_times_monotone_per_channel(ops):
    """A channel never runs two transfers at once: starts/completions on
    one (pair, channel) are non-decreasing in issue order."""
    net = _mknet()
    _run_ops(net, ops)
    last_completion = {}
    for src, dst, _method, _nbytes, ch, start, completion in net.trace:
        key = ((min(src, dst), max(src, dst)), ch)
        assert completion >= start
        prev = last_completion.get(key)
        if prev is not None:
            assert start >= prev - 1e-12      # queued behind, never inside
        last_completion[key] = completion


@given(OPS)
@settings(max_examples=50, deadline=None)
def test_overlapped_elapsed_le_serial_sum(ops):
    """Channels only ever help: the drained batch's elapsed virtual time
    is bounded by the sum of the individual transfer times."""
    net = _mknet()
    t0 = net.clock
    issued = _run_ops(net, ops)
    elapsed = net.clock - t0
    serial_sum = sum(t.elapsed for t in issued)
    assert elapsed <= serial_sum + 1e-9
    # and the clock landed exactly on the latest completion
    if issued:
        assert abs(net.clock - max(t.completion for t in issued)) < 1e-12


@given(st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=10, deadline=None)
def test_same_seed_identical_clock_trace(seed):
    """Same seed => identical reservation trace and final clock."""

    def one_run():
        rng = random.Random(seed)
        net = _mknet()
        ops = [(rng.randrange(N_EPS), rng.randrange(N_EPS),
                rng.randrange(2 * 1024 * 1024), rng.random() < 0.5)
               for _ in range(32)]
        _run_ops(net, ops)
        return net.trace, net.clock

    trace1, clock1 = one_run()
    trace2, clock2 = one_run()
    assert trace1 == trace2
    assert clock1 == clock2


def test_striped_elapsed_is_max_over_stripes_not_sum():
    """Acceptance: a striped send's clock charge equals the slowest
    stripe channel, far below the serial sum of the stripes."""
    net = _mknet(latency=0.030)
    xfer = StripedTransfer(net)
    payload = b"s" * (48 * 1024 * 1024)
    t0 = net.clock
    xfer.send("e0", "e1", payload)
    elapsed = net.clock - t0
    stripes = [row for row in net.trace if row[2] == "stripe"]
    assert len(stripes) == MAX_STRIPES
    durations = [comp - start for *_head, start, comp in stripes]
    assert abs(elapsed - max(durations)) < 1e-9      # all start together
    assert elapsed < sum(durations) / (MAX_STRIPES / 2)


def test_chained_transfer_starts_after_dependency():
    """``not_before`` serializes causally-dependent transfers (a write
    ack cannot start before its data lands) even on an idle channel."""
    net = _mknet()
    data = net.transfer("e0", "e1", "data", 1024 * 1024)
    ack = net.transfer("e1", "e0", "ack", not_before=data.completion)
    assert ack.start >= data.completion
    net.drain()
    assert net.clock == ack.completion


def test_fire_and_forget_does_not_accumulate_outstanding():
    """Transfers nobody waits on must not grow the bookkeeping without
    bound (nor slow later calls): records the clock has passed age out."""
    net = _mknet()
    for _ in range(2000):
        net.transfer("e0", "e1", "ff", 1000)
        net.advance(0.5)                 # clock sails past the completion
    assert len(net._event_heap) < 600
    assert net.outstanding() == []       # nothing actually in flight
    assert net.drain() == net.clock      # and drain is a no-op


def test_trace_is_bounded_and_deterministically_truncated():
    net = _mknet()
    net.trace_limit = 100
    for _ in range(300):
        net.wait(net.transfer("e0", "e1", "op", 10))
    assert len(net.trace) == 100
    assert net.rpc_count == 300          # accounting unaffected by the cap


def test_channel_pool_queues_beyond_cap():
    """More concurrent transfers than channels: the extras queue behind
    the earliest-free channel — wave behavior, still deterministic."""
    net = _mknet()
    n = net.channels_per_pair
    ts = [net.transfer("e0", "e1", "op", 1000) for _ in range(n + 3)]
    starts = sorted(t.start for t in ts)
    assert starts[0] == starts[n - 1] == net.clock       # first wave together
    assert starts[n] > net.clock                         # overflow queued
    assert len({t.channel for t in ts}) == n
    net.drain()
