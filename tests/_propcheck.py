"""Property-check shim: real hypothesis when installed, else a tiny
deterministic stand-in.

The tier-1 suite must collect and run on machines without ``hypothesis``
(the container does not bake it in).  Test modules import::

    from _propcheck import given, settings, strategies as st

When the real package is importable those names are re-exports and behave
exactly like hypothesis.  Otherwise the shim below provides the subset of
the surface this suite uses — ``given`` with positional strategies (filled
into the rightmost test parameters, hypothesis-style, so pytest fixtures on
the left keep working), ``settings(max_examples=..., deadline=...)``, and
the ``integers`` / ``binary`` / ``lists`` / ``tuples`` / ``sampled_from`` /
``booleans`` / ``floats`` / ``just`` strategies — as a seeded random case
generator.  Cases are reproducible: the seed defaults to
:data:`DEFAULT_SEED` and can be overridden from the command line via
``pytest --seed N`` (see ``conftest.py``).  No shrinking; a failure reports
the drawn example and chains the original error.
"""
from __future__ import annotations

try:                                    # real-hypothesis-first
    from hypothesis import given, settings, strategies  # noqa: F401
    USING_HYPOTHESIS = True
except ImportError:
    USING_HYPOTHESIS = False

# Overridden by conftest.py when `pytest --seed N` is passed.  Only the
# shim consumes it; real hypothesis manages its own seeding.
GLOBAL_SEED = None
DEFAULT_SEED = 0xA11CE
DEFAULT_MAX_EXAMPLES = 25

if not USING_HYPOTHESIS:
    import inspect
    import random
    import types

    class _Strategy:
        def __init__(self, name, draw):
            self._name = name
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def __repr__(self):
            return self._name

    def _integers(min_value=-(2 ** 31), max_value=2 ** 31):
        def draw(rng):
            # bias toward the boundaries: that is where stripe/WAL logic
            # breaks, and where hypothesis would shrink to anyway
            r = rng.random()
            if r < 0.10:
                return min_value
            if r < 0.20:
                return max_value
            return rng.randint(min_value, max_value)
        return _Strategy(f"integers({min_value}, {max_value})", draw)

    def _binary(min_size=0, max_size=64):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return bytes(rng.getrandbits(8) for _ in range(n))
        return _Strategy(f"binary({min_size}, {max_size})", draw)

    def _lists(elements, min_size=0, max_size=16):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(f"lists({elements!r})", draw)

    def _tuples(*elems):
        return _Strategy(f"tuples({', '.join(map(repr, elems))})",
                         lambda rng: tuple(e.draw(rng) for e in elems))

    def _sampled_from(seq):
        choices = list(seq)
        return _Strategy(f"sampled_from({choices!r})",
                         lambda rng: rng.choice(choices))

    def _booleans():
        return _Strategy("booleans()", lambda rng: rng.random() < 0.5)

    def _floats(min_value=0.0, max_value=1.0, **_ignored):
        return _Strategy(f"floats({min_value}, {max_value})",
                         lambda rng: rng.uniform(min_value, max_value))

    def _just(value):
        return _Strategy(f"just({value!r})", lambda rng: value)

    strategies = types.SimpleNamespace(
        integers=_integers, binary=_binary, lists=_lists, tuples=_tuples,
        sampled_from=_sampled_from, booleans=_booleans, floats=_floats,
        just=_just,
    )

    def settings(**kw):
        """Record run options (only ``max_examples`` is honored)."""
        def deco(fn):
            fn._pc_settings = kw
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            sig = inspect.signature(fn)
            params = list(sig.parameters)
            strat_map = dict(kw_strats)
            if arg_strats:
                # hypothesis fills positional strategies from the RIGHT,
                # leaving leading parameters for pytest fixtures
                free = [p for p in params if p not in strat_map]
                for name, strat in zip(free[len(free) - len(arg_strats):],
                                       arg_strats):
                    strat_map[name] = strat
            fixture_params = [sig.parameters[p] for p in params
                              if p not in strat_map]

            def wrapper(*a, **kw):
                cfg = getattr(wrapper, "_pc_settings", {})
                n = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
                seed = GLOBAL_SEED if GLOBAL_SEED is not None \
                    else DEFAULT_SEED
                rng = random.Random(
                    f"{seed}:{fn.__module__}.{fn.__qualname__}")
                for i in range(n):
                    drawn = {name: s.draw(rng)
                             for name, s in strat_map.items()}
                    try:
                        fn(*a, **kw, **drawn)
                    except Exception as e:
                        raise AssertionError(
                            f"{fn.__name__}: falsifying example {i + 1}/{n}"
                            f" (seed={seed}, rerun with `pytest --seed"
                            f" {seed}`): {drawn!r}") from e

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._pc_settings = getattr(fn, "_pc_settings", {})
            # pytest must see only the fixture parameters
            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            return wrapper
        return deco
