"""Striped WAL-backed checkpointing: commit ordering, recovery, GC."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Fabric, FabricSpec
from repro.checkpoint import CheckpointManager


@pytest.fixture()
def session(tmp_path):
    return Fabric(FabricSpec.star(str(tmp_path / "h"),
                                  str(tmp_path / "s"))).login("sci")


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (32, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((32, 16)) * 0.5,
                "count": jnp.asarray(7, jnp.int32)},
    }


def test_save_restore_roundtrip(session):
    s = session
    mgr = CheckpointManager(s.client, "home/ckpt")
    tree = _tree()
    mgr.save(10, tree, extra={"data": {"cursor": 1234}})
    s.client.sync()
    restored, manifest = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert manifest["step"] == 10
    assert manifest["extra"]["data"]["cursor"] == 1234
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_wal_fifo_commit_ordering(session):
    """The LATEST pointer must reach home only after every leaf it names:
    drain the WAL op-by-op and verify a restore is possible the moment
    LATEST lands (no torn checkpoints, paper's last-close-wins commit)."""
    s = session
    mgr = CheckpointManager(s.client, "home/ckpt")
    tree = _tree()
    mgr.save(5, tree)
    saw_latest = False
    while s.client.oplog.pending():
        s.client.pump(max_ops=1)
        try:
            data, _ = s.server.store.get(s.token, "home/ckpt/LATEST")
            saw_latest = True
        except FileNotFoundError:
            continue
        # LATEST visible => the full manifest + leaves must be restorable
        base = f"home/ckpt/step_{int(data.decode()):08d}"
        mdata, _ = s.server.store.get(s.token, base + "/MANIFEST.json")
        manifest = json.loads(mdata.decode())
        for leaf in manifest["leaves"]:
            s.server.store.get(s.token, leaf["path"])   # must not raise
    assert saw_latest


def test_crash_before_sync_recovers_via_wal(session, tmp_path):
    """Trainer crashes after save() but before any pump: a fresh client
    over the same WAL replays everything (paper §3.1 recovery tool)."""
    s = session
    mgr = CheckpointManager(s.client, "home/ckpt")
    tree = _tree()
    mgr.save(3, tree)
    # crash: nothing flushed. New client process over the same oplog dir:
    from repro.core.namespace import XufsClient
    c2 = XufsClient("site", s.network, cache_root=s.client.cache.root,
                    oplog_root=s.client.oplog.root, owner="sci")
    c2.mount("home/", "home", s.server.store, s.token)
    assert len(c2.oplog.pending()) > 0
    c2.sync()
    mgr2 = CheckpointManager(c2, "home/ckpt")
    restored, manifest = mgr2.restore(jax.tree.map(jnp.zeros_like, tree))
    assert manifest["step"] == 3
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(tree["params"]["w"]))


def test_latest_points_to_newest(session):
    s = session
    mgr = CheckpointManager(s.client, "home/ckpt")
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    s.client.sync()
    assert mgr.latest_step() == 2
    restored, manifest = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()))
    assert manifest["step"] == 2


def test_gc_keeps_recent(session):
    s = session
    mgr = CheckpointManager(s.client, "home/ckpt", keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _tree(step))
    s.client.sync()
    mgr.gc()
    s.client.sync()
    steps = mgr.list_steps()
    assert 3 in steps and 4 in steps and 1 not in steps
