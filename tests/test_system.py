"""End-to-end system test: the paper's workflow (§2.1) on the full stack.

develop locally -> mount at the pod -> prefetch sources -> cache input ->
train with write-behind checkpoints -> survive a WAN disconnect mid-run ->
analyze results back at home -> raw output stays localized.
"""
import numpy as np
import pytest

from repro.core import Fabric, FabricSpec, MountSpec
from repro.config import RunConfig, ShapeConfig, OptimConfig
from repro.configs import get_tiny_config
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticCorpus, DataPipeline
from repro.train import Trainer


def test_full_workflow(tmp_path):
    fab = Fabric(FabricSpec.star(str(tmp_path / "laptop"),
                                 str(tmp_path / "pod")))
    net = fab.network
    s = fab.login("sci", mounts=[MountSpec("home/",
                                           ("home/scratch/raw/",))])
    cfg = get_tiny_config("qwen3-4b")

    # 1-3: code + input data prepared at home, imported at the pod
    for i in range(8):
        s.server.store.put(s.token, f"home/src/mod{i}.py", b"# sim\n" * 100)
    assert s.client.chdir("home/src") == 8        # parallel prefetch
    SyntheticCorpus(s.client, "home/input", seed=1, vocab=cfg.vocab_size,
                    shard_tokens=4096).materialize(2)

    # 4: the run — write-behind checkpoints, localized raw dumps
    pipe = DataPipeline(s.client, "home/input", cfg, batch=4, seq=32,
                        n_shards=2)
    run = RunConfig(model=cfg, shape=ShapeConfig("sys", "train", 32, 4),
                    optim=OptimConfig(lr=1e-3, warmup_steps=3,
                                      total_steps=50))
    ckpt = CheckpointManager(s.client, "home/ckpt")
    tr = Trainer(run, pipe, ckpt, ckpt_every=4)
    res1 = tr.train(6)
    with s.client.open("home/scratch/raw/activations.bin", "w") as f:
        f.write(b"\x00" * 1_000_000)

    # the laptop drops off the WAN mid-run: training continues
    net.partition("pod", "laptop")
    res2 = tr.train(6)
    assert len(res2.losses) == 6                  # no stall
    assert len(s.client.oplog.pending()) > 0      # checkpoints queued

    # 5-6: reconnect; queue drains; results appear at home in WAL order
    net.heal("pod", "laptop")
    s.client.sync()
    assert ckpt.latest_step() == 12
    restored, manifest = ckpt.restore(tr._state_tree())
    np.testing.assert_allclose(np.asarray(restored["params"]["final_norm"]),
                               np.asarray(tr.params["final_norm"]))

    # 7: raw output never crossed the WAN
    with pytest.raises(FileNotFoundError):
        s.server.store.get(s.token, "home/scratch/raw/activations.bin")
    # and the losses behaved
    assert np.isfinite(res1.losses + res2.losses).all()
