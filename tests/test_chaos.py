"""Randomized chaos property test: two writers hammer one path while a
seeded :class:`FaultPlan` cuts links at random; after the chaos horizon
passes and both writers drain + reconcile, the fabric must have
converged — no parked or pending work, home holding exactly one written
payload, replicas matching home, and every surviving conflict preserving
both branches.  Same seed ⇒ bit-identical wire trace.

Runs under real hypothesis when installed, else the deterministic
``_propcheck`` shim (``pytest --seed N`` reruns a failure).
"""
import shutil
import tempfile

from _propcheck import given, settings, strategies as st

from repro.core import (
    Fabric, FabricSpec, FaultPlan, LinkModel, MountSpec, ReplicaPolicy,
    SiteSpec, WriteLeaseSpec,
)
from repro.core.oplog import vts_dominates

HOME_LATENCY = 0.060
PATH = "home/shared/chaos.bin"
PAIRS = (("site", "home"), ("site2", "home"),
         ("home", "r1"), ("home", "r2"))
ROUNDS = 4
HORIZON_S = 50.0


def _run(root, seed, lease):
    spec = FabricSpec.star(
        f"{root}/home", f"{root}/site",
        replica_latencies={"r1": 0.005, "r2": 0.015},
        link=LinkModel(latency_s=HOME_LATENCY),
        extra_sites=(SiteSpec("site2", root=f"{root}/site2"),))
    fab = Fabric(spec)
    s = fab.login("sci", replicas=ReplicaPolicy(
        sites=("r1", "r2"), write_quorum="majority",
        write_lease=WriteLeaseSpec(ttl_s=10.0) if lease else None))
    bob = fab.attach(s, "site2", owner="bob", mounts=[MountSpec("home/")])
    net = s.network
    t0 = net.clock
    fab.arm_faults(FaultPlan.chaos(PAIRS, seed=seed, horizon_s=HORIZON_S,
                                   events=6, start_s=t0))
    writers = ((s.client, "sci"), (bob, "bob"))
    payloads = set()
    for rnd in range(ROUNDS):
        for client, owner in writers:
            data = f"{owner}:{rnd}:".encode() * 997
            payloads.add(data)
            with client.open(PATH, "w") as f:
                f.write(data)
            client.pump()         # may park, defer, or land — all fine
        net.advance(HORIZON_S / ROUNDS)
        for client, _ in writers:
            client.pump()
            client.reconcile()
    # past the horizon every chaos window has lapsed (all are finite);
    # drain until the whole fabric is quiet
    net.advance(max(0.0, t0 + HORIZON_S - net.clock) + 15.0)
    for _ in range(3):
        for client, _ in writers:
            client.pump()
            client.reconcile()
    s.replicas.resync()
    home_data, home_st = s.server.store.get(s.token, PATH)
    return {
        "trace": tuple(net.trace),
        "home_data": home_data,
        "home_version": home_st.version,
        "home_vts": s.server.store.vts_of(PATH),
        "replicas": {name: (rep.store.get(rep.token, PATH)[0],
                            rep.store.vts_of(PATH))
                     for name, rep in s.replicas.replicas.items()},
        "payloads": payloads,
        "pending": [r.path for c, _ in writers for r in c.oplog.pending()],
        "parked": [r.path for c, _ in writers
                   for r in c.oplog.unreconciled()],
        "conflicts": [c for cl, _ in writers for c in cl.conflicts],
    }


def _check_invariants(out):
    # 1. nothing left queued or parked anywhere
    assert out["pending"] == [], f"undrained ops: {out['pending']}"
    assert out["parked"] == [], f"unreconciled ops: {out['parked']}"
    # 2. home holds exactly one of the payloads that was actually written
    assert out["home_data"] in out["payloads"]
    # 3. replicas converge to home's bytes and home's frontier dominates
    for name, (data, vts) in out["replicas"].items():
        assert data == out["home_data"], f"{name} diverged from home"
        assert vts_dominates(out["home_vts"], vts), \
            f"{name} frontier {vts} escapes home {out['home_vts']}"
    # 4. a detected conflict preserves BOTH branches verbatim
    for c in out["conflicts"]:
        assert c.ours_data in out["payloads"]
        assert c.theirs_data in out["payloads"]
        assert c.ours_vts and c.theirs_vts


@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 20),
       st.booleans())
def test_chaos_converges_and_loses_nothing(seed, lease):
    root = tempfile.mkdtemp(prefix="chaos_")
    try:
        _check_invariants(_run(root, seed, lease))
    finally:
        shutil.rmtree(root, ignore_errors=True)


@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 20))
def test_same_seed_same_trace(seed):
    """The whole run — workload + chaos — is a pure function of the
    seed: two fresh universes produce bit-identical wire traces and the
    same resolved state."""
    roots = [tempfile.mkdtemp(prefix="chaos_det_") for _ in range(2)]
    try:
        a = _run(roots[0], seed, lease=False)
        b = _run(roots[1], seed, lease=False)
        assert a["trace"] == b["trace"]
        assert a["home_data"] == b["home_data"]
        assert a["home_vts"] == b["home_vts"]
        assert a["home_version"] == b["home_version"]
    finally:
        for r in roots:
            shutil.rmtree(r, ignore_errors=True)
