"""Capacity-aware replica placement & eviction.

The tentpole invariants: per-replica byte accounting is exact; a bounded
replica admits on demand instead of mirroring at resync; the scheduled
``evict:`` task trims to the low watermark under per-path locks; and the
three protection classes — quorum-parked, freshness-floor, repair-lease
held — are never evicted (property test).  Read repair is the
re-placement path for an evicted-then-hot-again file (regression test).
"""
import dataclasses
import itertools

import pytest

from _propcheck import given, settings, strategies as st
from repro.core import (
    EvictionSpec, Fabric, FabricSpec, KB, LinkModel, MB, MaintenanceSpec,
    ReplicaPolicy,
)

HOME_LATENCY = 0.060

#: long-period everything: isolates the evict task on the scheduler
QUIET = MaintenanceSpec(resync_period_s=1e6, repair_period_s=1e6,
                        lease_period_s=1e6, reconcile_period_s=1e6,
                        lock_lease_s=120.0)


def efab(tmp_path, tag="e", maintenance=None):
    spec = FabricSpec.star(str(tmp_path / f"home-{tag}"),
                           str(tmp_path / f"site-{tag}"),
                           replica_latencies={"r1": 0.005},
                           link=LinkModel(latency_s=HOME_LATENCY))
    if maintenance is not None:
        spec = dataclasses.replace(spec, maintenance=maintenance)
    return Fabric(spec)


def elogin(tmp_path, ev, tag="e", maintenance=None):
    fab = efab(tmp_path, tag=tag, maintenance=maintenance)
    return fab.login("sci", replicas=ReplicaPolicy(sites=("r1",),
                                                   eviction=ev))


def put(s, path, payload):
    with s.client.open(path, "w") as f:
        f.write(payload)
    s.client.pump()


# ---- spec validation --------------------------------------------------------

@pytest.mark.parametrize("kw", [
    dict(capacity=0),
    dict(capacity=-1),
    dict(capacity=1, high_watermark=1.5),
    dict(capacity=1, low_watermark=0.0),
    dict(capacity=1, high_watermark=0.5, low_watermark=0.6),
    dict(capacity=1, policy="random"),
    dict(capacity=1, scan_period_s=0.0),
])
def test_eviction_spec_validation(kw):
    with pytest.raises(ValueError):
        EvictionSpec(**kw)


def test_watermark_byte_thresholds():
    ev = EvictionSpec(capacity=1000, high_watermark=0.9, low_watermark=0.5)
    assert ev.high_bytes == 900 and ev.low_bytes == 500


# ---- byte accounting --------------------------------------------------------

def test_accounting_tracks_resident_bytes_even_unbounded(tmp_path):
    s = efab(tmp_path, tag="acct").login(
        "sci", replicas=ReplicaPolicy(sites=("r1",)))
    put(s, "home/d/a.bin", b"A" * (1 * MB))
    rep = s.replicas.replicas["r1"]
    assert rep.resident == {"home/d/a.bin": 1 * MB}
    assert rep.resident_bytes == 1 * MB
    # overwrite replaces, never double-counts
    put(s, "home/d/a.bin", b"B" * (2 * MB))
    assert rep.resident_bytes == 2 * MB
    assert rep.peak_resident_bytes == 2 * MB
    assert rep.fills["home/d/a.bin"] == 2
    # a propagated delete releases the bytes
    s.client.unlink("home/d/a.bin")
    s.client.pump()
    assert rep.resident == {} and rep.resident_bytes == 0
    assert rep.peak_resident_bytes == 2 * MB      # high-water survives


def test_admission_refuses_when_full_without_marking_lagging(tmp_path):
    s = elogin(tmp_path, EvictionSpec(capacity=1 * MB), tag="adm")
    put(s, "home/d/big.bin", b"A" * (2 * MB))     # home acks; replica full
    rset, rep = s.replicas, s.replicas.replicas["r1"]
    assert rset.admission_refused == 1
    assert "home/d/big.bin" not in rep.resident
    # crucially NOT lagging: a scheduled repair must not spin on refusal
    assert "home/d/big.bin" not in rep.lagging
    assert rset.repair_targets() == []


# ---- hot-set-only fill / demand placement -----------------------------------

def test_evicted_path_refills_via_read_repair_not_resync(tmp_path):
    s = elogin(tmp_path, EvictionSpec(capacity=4 * MB), tag="hot")
    path, payload = "home/d/x.bin", b"X" * (1 * MB)
    put(s, path, payload)
    rset, rep = s.replicas, s.replicas.replicas["r1"]
    assert path in rep.resident
    assert rset.evict_path("r1", path) == 1 * MB
    assert rep.resident_bytes == 0 and rep.evictions == 1
    # anti-entropy must NOT re-mirror the cold evicted path...
    assert rset.resync() == 0
    assert path not in rep.resident
    # ...the next hot read re-places it: read repair IS placement
    s.client.cache.evict(path)                    # force a cold fill
    with s.client.open(path) as f:
        assert f.read() == payload
    assert path in rep.resident
    assert rset.read_repairs >= 1


def test_unbounded_set_still_mirrors_at_resync(tmp_path):
    s = efab(tmp_path, tag="mir").login(
        "sci", replicas=ReplicaPolicy(sites=("r1",)))
    # seed home directly: the replica never saw a fan-out
    s.server.store.put(s.token, "home/d/cold.bin", b"C" * (64 * KB))
    assert s.replicas.resync() == 1               # mirrored (no capacity)
    assert "home/d/cold.bin" in s.replicas.replicas["r1"].resident


# ---- the scheduled evict task -----------------------------------------------

def test_scheduled_evict_trims_lru_to_low_watermark(tmp_path):
    ev = EvictionSpec(capacity=640 * KB, high_watermark=0.9,
                      low_watermark=0.5, scan_period_s=10.0)
    s = elogin(tmp_path, ev, tag="trim", maintenance=QUIET)
    for i in range(10):
        put(s, f"home/d/f{i}.bin", bytes([65 + i]) * (64 * KB))
    rep = s.replicas.replicas["r1"]
    assert rep.resident_bytes == 640 * KB         # at capacity, over high
    # touch f0/f1 so they are the hottest; f2.. are the LRU victims
    for i in (0, 1):
        s.client.cache.evict(f"home/d/f{i}.bin")
        with s.client.open(f"home/d/f{i}.bin") as f:
            f.read()
    s.scheduler.run_until(s.network.clock + ev.scan_period_s + 0.5)
    assert rep.resident_bytes <= ev.low_bytes
    assert rep.evictions == 5                     # 640K -> 320K @ 64K each
    assert {"home/d/f0.bin", "home/d/f1.bin"} <= set(rep.resident)
    r = s.maintenance_report()
    assert r.evictions == 5 and r.double_repairs == 0
    assert any(name.startswith("evict:") for name in r.tasks)


def test_evict_task_dead_letters_under_partition_and_revives(tmp_path):
    ev = EvictionSpec(capacity=128 * KB, high_watermark=0.5,
                      low_watermark=0.25, scan_period_s=10.0)
    s = elogin(tmp_path, ev, tag="dl", maintenance=QUIET)
    put(s, "home/d/a.bin", b"A" * (128 * KB))     # fills to capacity
    rep = s.replicas.replicas["r1"]
    assert rep.resident_bytes > ev.high_bytes
    net = s.network
    net.partition("site", "r1")                   # scan probe now fails
    t0 = net.clock
    s.scheduler.run_until(t0 + 40.0)
    r = s.maintenance_report()
    assert r.dead_lettered == 1
    (task_name,) = [d.task for d in r.dead_letters]
    assert task_name.startswith("evict:")
    assert rep.resident_bytes > ev.high_bytes     # nothing silently evicted
    net.heal("site", "r1")
    s.scheduler.revive(task_name)
    s.scheduler.run_until(net.clock + ev.scan_period_s + 0.5)
    assert rep.resident_bytes <= ev.low_bytes     # trim landed post-heal


# ---- protections (property test) --------------------------------------------

_SEQ = itertools.count()


@given(st.lists(st.sampled_from(["plain", "parked", "floor", "locked"]),
                min_size=1, max_size=10))
@settings(max_examples=25, deadline=None)
def test_eviction_never_removes_protected_paths(tmp_path, kinds):
    """Whatever the mix, a full-trim scan only ever removes plain paths:
    quorum-parked (replica copies are the only durable bytes),
    freshness-floor (replica holds newer than home), and repair-lease
    held paths all survive."""
    ev = EvictionSpec(capacity=len(kinds) * 16 * KB,
                      high_watermark=0.5, low_watermark=0.01,
                      scan_period_s=5.0)
    s = elogin(tmp_path, ev, tag=f"prop{next(_SEQ)}", maintenance=QUIET)
    rset, sched, net = s.replicas, s.scheduler, s.network
    rep = rset.replicas["r1"]
    key = sched.rset_key(rset)
    paths = []
    for i, kind in enumerate(kinds):
        p = f"home/d/{kind}{i}.bin"
        put(s, p, b"x" * (16 * KB))
        paths.append((p, kind))
    assert rep.resident_bytes == ev.capacity      # all admitted, over high
    for p, kind in paths:
        hv = rset.catalog.home_version(p)
        if kind == "parked":
            rset.catalog.note_quorum(p, hv + 1)
        elif kind == "floor":
            rset.catalog.record(p, "r1", hv + 1)  # replica newer than home
        elif kind == "locked":
            assert sched.locks.acquire(f"{key}/{p}", "peer@elsewhere",
                                       now=net.clock)
    sched.run_until(net.clock + ev.scan_period_s + 0.5)
    survivors = set(rep.resident)
    for p, kind in paths:
        if kind == "plain":
            assert p not in survivors, "full trim leaves no plain path"
        else:
            assert p in survivors, f"{kind} path was evicted"
    assert s.maintenance_report().evictions == \
        sum(1 for _, k in paths if k == "plain")
