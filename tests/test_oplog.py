"""Meta-operation queue (WAL): ordering, replay, crash tolerance."""
import json
import os

import pytest
from _propcheck import given, settings, strategies as st

from repro.core.oplog import MetaOpQueue, PENDING, DONE
from repro.core.transport import DisconnectedError


def test_append_flush_order(tmp_path):
    q = MetaOpQueue(str(tmp_path))
    applied = []
    q.append("store", "a", b"1")
    q.append("store", "b", b"2")
    q.append("delete", "a")
    q.flush(lambda rec, data: applied.append((rec.op, rec.path, data)))
    assert applied == [("store", "a", b"1"), ("store", "b", b"2"),
                       ("delete", "a", None)]
    assert q.pending() == []


def test_last_close_wins(tmp_path):
    """Multiple closes of the same path ship only the newest content."""
    q = MetaOpQueue(str(tmp_path))
    q.append("store", "f", b"v1")
    q.append("store", "f", b"v2")
    q.append("store", "f", b"v3")
    applied = []
    q.flush(lambda rec, data: applied.append(data))
    assert applied == [b"v3"]


def test_disconnect_stops_drain_and_resumes(tmp_path):
    q = MetaOpQueue(str(tmp_path))
    q.append("store", "a", b"1")
    q.append("store", "b", b"2")
    calls = []

    def flaky(rec, data):
        if rec.path == "b":
            raise DisconnectedError("down")
        calls.append(rec.path)

    n = q.flush(flaky)
    assert n == 1 and calls == ["a"]
    assert [r.path for r in q.pending()] == ["b"]
    n = q.flush(lambda rec, data: calls.append(rec.path))
    assert n == 1 and calls == ["a", "b"]


def test_replay_after_crash_reopens_pending(tmp_path):
    q = MetaOpQueue(str(tmp_path))
    q.append("store", "x", b"data")
    # simulate crash: new instance over the same WAL
    q2 = MetaOpQueue(str(tmp_path))
    recs = q2.pending()
    assert len(recs) == 1 and recs[0].path == "x"
    applied = []
    q2.flush(lambda rec, data: applied.append(data))
    assert applied == [b"data"]


def test_torn_tail_line_is_skipped(tmp_path):
    q = MetaOpQueue(str(tmp_path))
    q.append("store", "x", b"data")
    with open(q.wal_path, "a") as f:
        f.write('{"seq": 99, "op": "sto')   # torn write at crash
    q2 = MetaOpQueue(str(tmp_path))
    assert [r.path for r in q2.pending()] == ["x"]
    assert q2._next_seq >= 2


def test_seq_monotonic_across_restart(tmp_path):
    q = MetaOpQueue(str(tmp_path))
    r1 = q.append("store", "x", b"1")
    q2 = MetaOpQueue(str(tmp_path))
    r2 = q2.append("store", "y", b"2")
    assert r2.seq > r1.seq


@given(st.lists(st.tuples(st.sampled_from(["p1", "p2", "p3"]),
                          st.binary(min_size=1, max_size=8)),
                min_size=1, max_size=20))
@settings(max_examples=30, deadline=None)
def test_flush_applies_newest_per_path(tmp_path_factory, ops):
    """Property: after drain, the applied content per path is the LAST
    appended content for that path (last-close-wins), and every path
    appended is applied exactly once."""
    root = tmp_path_factory.mktemp("wal")
    q = MetaOpQueue(str(root))
    for path, data in ops:
        q.append("store", path, data)
    final = {}
    q.flush(lambda rec, data: final.__setitem__(rec.path, data))
    expect = {}
    for path, data in ops:
        expect[path] = data
    assert final == expect
    assert q.pending() == []


def test_compaction_preserves_pending(tmp_path):
    q = MetaOpQueue(str(tmp_path), compact_threshold=4)
    for i in range(10):
        q.append("store", f"p{i}", bytes([i]))
    q.flush(lambda rec, data: None, max_ops=5)
    q.compact()
    remaining = [r.path for r in q.pending()]
    assert remaining == [f"p{i}" for i in range(5, 10)]
