"""End-to-end XUFS fabric: caching, callbacks, disconnected ops, security."""
import os

import pytest

from repro.core import (
    AuthError, DisconnectedError, Fabric, FabricSpec, KeyPhrase, MountSpec,
)
from repro.core.transport import respond, verify, make_challenge


def plain_fabric(tmp_path) -> Fabric:
    return Fabric(FabricSpec.star(str(tmp_path / "home"),
                                  str(tmp_path / "site")))


@pytest.fixture()
def session(tmp_path):
    return plain_fabric(tmp_path).login(
        "sci", mounts=[MountSpec("home/", ("home/scratch/raw/",))])


def test_whole_file_cache_hit_after_first_open(session):
    s = session
    s.server.store.put(s.token, "home/data/a.bin", b"A" * 100_000)
    with s.client.open("home/data/a.bin") as f:
        assert f.read() == b"A" * 100_000
    misses0 = s.client.cache.misses
    clock0 = s.client.network.clock
    with s.client.open("home/data/a.bin") as f:
        assert f.read() == b"A" * 100_000
    assert s.client.cache.misses == misses0        # no refetch
    assert s.client.network.clock == clock0        # zero WAN time


def test_opendir_populates_attrs_without_data(session):
    s = session
    for i in range(5):
        s.server.store.put(s.token, f"home/src/f{i}.c", b"x" * 200_000)
    s.client.opendir("home/src")
    # stat() served from hidden attr files: no further RPC
    rpc0 = s.client.network.rpc_count
    st = s.client.stat("home/src/f3.c")
    assert st is not None and st.size == 200_000
    assert s.client.network.rpc_count == rpc0


def test_write_behind_never_blocks_and_syncs(session):
    s = session
    clock0 = s.client.network.clock
    with s.client.open("home/out/result.dat", "w") as f:
        f.write(b"R" * 300_000)
    assert s.client.network.clock == clock0   # close() returned locally
    assert len(s.client.oplog.pending()) == 1
    s.client.sync()
    data, st = s.server.store.get(s.token, "home/out/result.dat")
    assert data == b"R" * 300_000


def test_localized_dir_never_ships_home(session):
    s = session
    with s.client.open("home/scratch/raw/big.out", "w") as f:
        f.write(b"Z" * 500_000)
    assert s.client.oplog.pending() == []
    s.client.sync()
    with pytest.raises(FileNotFoundError):
        s.server.store.get(s.token, "home/scratch/raw/big.out")
    # but locally readable
    with s.client.open("home/scratch/raw/big.out") as f:
        assert f.read() == b"Z" * 500_000


def test_callback_invalidation_refetches(session):
    s = session
    s.server.store.put(s.token, "home/data/x", b"old")
    with s.client.open("home/data/x") as f:
        assert f.read() == b"old"
    s.server.store.put(s.token, "home/data/x", b"new contents")
    s.client.pump_callbacks()
    entry = s.client.cache.lookup("home/data/x")
    assert entry.state == "invalid"
    with s.client.open("home/data/x") as f:
        assert f.read() == b"new contents"


def test_disconnected_reads_from_cache_and_queues_writes(session):
    s = session
    s.server.store.put(s.token, "home/data/x", b"cached")
    with s.client.open("home/data/x") as f:
        f.read()
    s.client.network.partition("site", "home")
    with s.client.open("home/data/x") as f:
        assert f.read() == b"cached"          # stale-but-available
    with s.client.open("home/out/offline", "w") as f:
        f.write(b"queued")
    assert s.client.pump() == 0               # WAN down: stays queued
    s.client.network.heal("site", "home")
    assert s.client.pump() >= 1
    assert s.server.store.get(s.token, "home/out/offline")[0] == b"queued"


def test_uncached_read_while_disconnected_raises(session):
    s = session
    s.server.store.put(s.token, "home/data/never_opened", b"x")
    s.client.network.partition("site", "home")
    with pytest.raises(DisconnectedError):
        s.client.open("home/data/never_opened")


def test_server_crash_reconnect_revalidates(session):
    s = session
    s.server.store.put(s.token, "home/data/x", b"v1")
    with s.client.open("home/data/x") as f:
        f.read()
    s.client.pump_callbacks()   # drain the (version-stale) v1 notification
    # crash drops subscriptions; a direct put now yields NO callback
    s.server.store._subscribers.clear()
    st = s.server.store.put(s.token, "home/data/x", b"v2-silent")
    assert s.client.pump_callbacks() == 0
    # reconnect: re-register + version revalidation catches the change
    stale = s.client.reconnect()
    assert stale == 1
    with s.client.open("home/data/x") as f:
        assert f.read() == b"v2-silent"


def test_auth_challenge_rejects_wrong_key(tmp_path):
    s = plain_fabric(tmp_path).login("sci")
    wrong = KeyPhrase.generate()
    with pytest.raises(AuthError):
        s.server.store.authenticate(lambda ch: respond(wrong, ch))
    with pytest.raises(AuthError):
        s.server.store.get("bogus-token", "home/x")


def test_challenge_response_is_keyphrase_bound():
    kp1, kp2 = KeyPhrase.generate(), KeyPhrase.generate()
    ch = make_challenge()
    assert verify(kp1, ch, respond(kp1, ch))
    assert not verify(kp1, ch, respond(kp2, ch))


def test_lock_lease_expiry(session):
    s = session
    assert s.client.lock("home/data/shared")
    lm = s.client.leases["home/"]
    assert s.server.store.lock_owner("home/data/shared",
                                     s.client.network.clock) == "sci"
    # time passes beyond TTL without renewal -> lock expires
    s.client.network.advance(lm.ttl + 1)
    assert s.server.store.lock_owner("home/data/shared",
                                     s.client.network.clock) is None
    # renewal keeps it alive
    assert s.client.lock("home/data/shared")
    lm.renew_all()
    assert s.server.store.lock_owner("home/data/shared",
                                     s.client.network.clock) == "sci"


def test_localized_lock_is_local(session):
    s = session
    rpc0 = s.client.network.rpc_count
    assert s.client.lock("home/scratch/raw/file")
    assert s.client.network.rpc_count == rpc0   # no WAN RPC


def test_prefetch_small_files_on_chdir(session):
    s = session
    for i in range(30):
        s.server.store.put(s.token, f"home/src/s{i}.c", b"c" * 1000)
    s.server.store.put(s.token, "home/src/big.bin", b"B" * 200_000)
    n = s.client.chdir("home/src")
    assert n == 30                      # only the small files
    # all small files now served without WAN
    rpc0 = s.client.network.rpc_count
    for i in range(30):
        with s.client.open(f"home/src/s{i}.c") as f:
            assert f.read() == b"c" * 1000
    assert s.client.network.rpc_count == rpc0
    # big file still needs a fetch
    assert s.client.cache.lookup("home/src/big.bin").state == "empty"
