"""Declarative fabric spec: validation, shim trace-equivalence,
multi-user fabrics, remount restoration, deprecation.

The load-bearing suite for the topology API: the ``ussh_login`` shim and
an equivalent :class:`FabricSpec` must wire the network **bit-identically**
(same ``Network.trace``, same final clock) on every benchmark topology —
that is what keeps the PR 2-4 self-gating benchmark numbers valid after
the refactor.
"""
import warnings

import pytest

from repro.core import (
    EvictionSpec, Fabric, FabricSpec, LinkModel, LinkSpec, MB, MountSpec,
    Network, ReplicaPolicy, ReplicaSet, SiteSpec, ussh_login,
)
from repro.core import session as session_mod

HOME_LATENCY = 0.060
REPLICAS = {"r1": 0.005, "r2": 0.015}


def star_spec(tmp_path, tag, *, replicas=(), budgets=None,
              latency_s=HOME_LATENCY):
    """Deliberately hand-rolled, NOT FabricSpec.star: the trace
    equivalence below must compare the shim against an independently
    spelled spec, and the shim itself builds through FabricSpec.star."""
    budgets = budgets or {}
    sites = [SiteSpec("home", root=str(tmp_path / f"h-{tag}"),
                      nic_budget=budgets.get("home")),
             SiteSpec("site", root=str(tmp_path / f"s-{tag}"),
                      nic_budget=budgets.get("site"))]
    links = []
    for rname in replicas:
        sites.append(SiteSpec(rname, nic_budget=budgets.get(rname)))
        links.append(LinkSpec("site", rname, latency_s=REPLICAS[rname]))
    return FabricSpec(sites=tuple(sites), links=tuple(links),
                      link=LinkModel(latency_s=latency_s))


# ---- spec validation -------------------------------------------------------

def test_spec_rejects_duplicate_sites():
    with pytest.raises(ValueError, match="duplicate site"):
        FabricSpec(sites=(SiteSpec("a"), SiteSpec("a")))


def test_spec_rejects_link_to_undeclared_site():
    with pytest.raises(ValueError, match="undeclared site"):
        FabricSpec(sites=(SiteSpec("a"),),
                   links=(LinkSpec("a", "ghost", latency_s=0.01),))


def test_spec_rejects_duplicate_links():
    with pytest.raises(ValueError, match="duplicate link"):
        FabricSpec(sites=(SiteSpec("a"), SiteSpec("b")),
                   links=(LinkSpec("a", "b", latency_s=0.01),
                          LinkSpec("b", "a", latency_s=0.02)))


def test_link_spec_needs_exactly_one_override():
    with pytest.raises(ValueError, match="exactly one"):
        LinkSpec("a", "b")
    with pytest.raises(ValueError, match="exactly one"):
        LinkSpec("a", "b", latency_s=0.01, link=LinkModel())
    with pytest.raises(ValueError):
        LinkSpec("a", "a", latency_s=0.01)


def test_site_spec_rejects_nonpositive_budget():
    with pytest.raises(ValueError, match="NIC budget"):
        SiteSpec("a", nic_budget=0)


def test_mount_spec_validates_prefix_and_localized():
    with pytest.raises(ValueError, match="end with"):
        MountSpec("home")
    with pytest.raises(ValueError, match="not under"):
        MountSpec("home/", ("elsewhere/raw/",))
    assert MountSpec("home/", ["home/a/"]).localized == ("home/a/",)


def test_replica_policy_validates():
    with pytest.raises(ValueError, match="duplicate"):
        ReplicaPolicy(sites=("r1", "r1"))
    with pytest.raises(ValueError, match="write_quorum"):
        ReplicaPolicy(sites=("r1",), write_quorum="most")
    with pytest.raises(ValueError, match="write_quorum"):
        ReplicaPolicy(sites=("r1",), write_quorum=0)
    with pytest.raises(ValueError, match="capacity_bytes"):
        ReplicaPolicy(sites=("r1",), capacity_bytes=-5)


def test_attaching_network_with_divergent_default_link_rejected(tmp_path):
    spec = star_spec(tmp_path, "div")            # default 60 ms
    with pytest.raises(ValueError, match="default link"):
        Fabric(spec, network=Network())          # network default 30 ms
    # matching defaults attach fine (the shim path)
    Fabric(spec, network=Network(link=LinkModel(latency_s=HOME_LATENCY)))


def test_login_rejects_duplicate_mount_prefixes(tmp_path):
    fab = Fabric(star_spec(tmp_path, "dupm"))
    with pytest.raises(ValueError, match="duplicate mount"):
        fab.login("sci", mounts=[
            MountSpec("home/", ("home/scratch/",)), MountSpec("home/")])


def test_login_rejects_undeclared_replica_site(tmp_path):
    fab = Fabric(star_spec(tmp_path, "typo"))
    with pytest.raises(KeyError, match="ghost"):
        fab.login("sci", replicas=ReplicaPolicy(sites=("ghost",)))
    # a root override must not bypass the declared-site check
    with pytest.raises(KeyError, match="hme"):
        fab.login("sci", home="hme", home_root=str(tmp_path / "x"))


def test_login_requires_a_root(tmp_path):
    fab = Fabric(FabricSpec(sites=(SiteSpec("home"), SiteSpec("site"))))
    with pytest.raises(ValueError, match="root"):
        fab.login("sci")
    # the login-time override unblocks a rootless spec
    s = fab.login("sci", home_root=str(tmp_path / "h"),
                  site_root=str(tmp_path / "s"))
    assert s.client.cache.root.startswith(str(tmp_path / "s"))


def test_capacity_bytes_records_on_replica_set(tmp_path):
    # the deprecated alias assembles a default EvictionSpec and still
    # surfaces through the capacity_bytes property on the ReplicaSet
    fab = Fabric(star_spec(tmp_path, "cap", replicas=("r1",)))
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",),
                                                capacity_bytes=64 * MB))
    assert s.replicas.capacity_bytes == 64 * MB
    assert s.replicas.eviction == EvictionSpec(capacity=64 * MB)
    with pytest.raises(ValueError, match="capacity_bytes"):
        ReplicaSet(s.network, "home", s.server.store, s.token,
                   capacity_bytes=0)


def test_capacity_bytes_alias_warns_and_matches_spec():
    import repro.core.fabric as fabric_mod
    fabric_mod._CAPACITY_DEPRECATION_WARNED = False
    with pytest.warns(DeprecationWarning, match="capacity_bytes"):
        p = ReplicaPolicy(sites=("r1",), capacity_bytes=8 * MB)
    assert p.eviction == EvictionSpec(capacity=8 * MB)
    # warn-once: a second construction stays quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        ReplicaPolicy(sites=("r1",), capacity_bytes=8 * MB)
    # alias + explicit spec must agree
    with pytest.raises(ValueError, match="conflicting"):
        ReplicaPolicy(sites=("r1",), capacity_bytes=8 * MB,
                      eviction=EvictionSpec(capacity=9 * MB))
    # agreeing alias is tolerated without reassembly
    p2 = ReplicaPolicy(sites=("r1",), capacity_bytes=8 * MB,
                       eviction=EvictionSpec(capacity=8 * MB,
                                             policy="fill_cost"))
    assert p2.eviction.policy == "fill_cost"


def test_later_login_never_retimes_a_composed_link(tmp_path):
    """Two users sharing one home site + replica from different compute
    sites: the second login must not overwrite the link the first login
    composed — retiming a live session's fan-out path mid-run."""
    fab = Fabric(FabricSpec(
        sites=(SiteSpec("h", root=str(tmp_path / "h")),
               SiteSpec("pod1", root=str(tmp_path / "p1")),
               SiteSpec("pod2", root=str(tmp_path / "p2")),
               SiteSpec("r1")),
        links=(LinkSpec("pod1", "r1", latency_s=0.005),
               LinkSpec("pod2", "r1", latency_s=0.030)),
        link=LinkModel(latency_s=HOME_LATENCY)))
    fab.login("alice", home="h", site="pod1",
              replicas=ReplicaPolicy(sites=("r1",)))
    composed = fab.network.latency_between("h", "r1")
    assert composed == pytest.approx(HOME_LATENCY + 0.005)
    fab.login("bob", home="h", site="pod2",
              replicas=ReplicaPolicy(sites=("r1",)))
    assert fab.network.latency_between("h", "r1") == composed


def test_explicit_home_replica_link_overrides_composition(tmp_path):
    spec = star_spec(tmp_path, "comp", replicas=("r1", "r2"))
    override = spec.links + (LinkSpec("home", "r1", latency_s=0.001),)
    fab = Fabric(FabricSpec(sites=spec.sites, links=override,
                            link=spec.link))
    fab.login("sci", replicas=ReplicaPolicy(sites=("r1", "r2")))
    net = fab.network
    assert net.latency_between("home", "r1") == 0.001      # declared wins
    assert net.latency_between("home", "r2") == pytest.approx(
        HOME_LATENCY + REPLICAS["r2"])                     # composed


# ---- shim trace equivalence ------------------------------------------------

def _plain_workload(s):
    s.server.store.put(s.token, "home/data/a.bin", b"A" * 300_000)
    with s.client.open("home/data/a.bin") as f:
        assert f.read()
    s.client.opendir("home/data")
    s.client.stat("home/data/a.bin")
    with s.client.open("home/out/r.dat", "w") as f:
        f.write(b"R" * 200_000)
    s.client.sync()
    s.client.network.drain()


def _replica_workload(s):
    for i in range(4):
        s.server.store.put(s.token, f"home/d/f{i}.bin", b"x" * (1 * MB))
    s.replicas.resync()
    for i in range(4):
        with s.client.open(f"home/d/f{i}.bin") as f:
            assert f.read()
    s.client.network.partition("site", "r1")
    s.client.cache.evict("home/d/f0.bin")
    with s.client.open("home/d/f0.bin") as f:       # degrade to r2
        assert f.read()
    s.client.network.heal("site", "r1")
    s.client.network.drain()


def _quorum_workload(s):
    for i in range(3):
        with s.client.open(f"home/out/q{i}.dat", "w") as f:
            f.write(bytes([i + 1]) * 200_000)
    s.client.sync()
    s.client.network.drain()


def _budget_workload(s):
    for i in range(3):
        s.server.store.put(s.token, f"home/d/b{i}.bin", b"B" * (2 * MB))
    s.replicas.resync()
    for i in range(3):
        with s.client.open(f"home/d/b{i}.bin") as f:
            assert f.read()
    s.client.network.drain()


TOPOLOGIES = [
    ("plain", {}, None, _plain_workload),
    ("replicated", dict(replica_sites=dict(REPLICAS)),
     ReplicaPolicy(sites=tuple(REPLICAS)), _replica_workload),
    ("quorum", dict(replica_sites=dict(REPLICAS), write_quorum="majority"),
     ReplicaPolicy(sites=tuple(REPLICAS), write_quorum="majority"),
     _quorum_workload),
    ("budgeted", dict(replica_sites=dict(REPLICAS),
                      nic_budgets={"home": 100 * MB, "r1": 50 * MB}),
     ReplicaPolicy(sites=tuple(REPLICAS)), _budget_workload),
]


@pytest.mark.parametrize("tag,kwargs,policy,workload",
                         TOPOLOGIES, ids=[t[0] for t in TOPOLOGIES])
def test_shim_and_spec_wire_bit_identical_traces(tmp_path, tag, kwargs,
                                                 policy, workload):
    """The acceptance gate: for each benchmark topology the deprecated
    ``ussh_login`` shim and the equivalent FabricSpec produce
    bit-identical ``Network.trace`` and final clock over one workload —
    so every PR 2-4 self-gating number survives the refactor unchanged.
    """
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        net = Network(link=LinkModel(latency_s=HOME_LATENCY))
        shim = ussh_login("sci", net, str(tmp_path / f"sh-{tag}"),
                          str(tmp_path / f"ss-{tag}"),
                          mounts={"home/": ["home/scratch/"]}, **kwargs)
    workload(shim)

    budgets = kwargs.get("nic_budgets") or {}
    spec = star_spec(tmp_path, tag, replicas=tuple(REPLICAS)
                     if "replica_sites" in kwargs else (), budgets=budgets)
    s = Fabric(spec).login(
        "sci", mounts=[MountSpec("home/", ("home/scratch/",))],
        replicas=policy)
    workload(s)

    assert s.network.trace == shim.network.trace
    assert s.network.clock == shim.network.clock
    assert s.network.per_endpoint_bytes == shim.network.per_endpoint_bytes


# ---- multi-user fabrics ----------------------------------------------------

def two_user_fabric(tmp_path, *, pod_budget=None):
    """Two users, two home sites, ONE shared compute site ("pod")."""
    spec = FabricSpec(
        sites=(SiteSpec("home1", root=str(tmp_path / "h1")),
               SiteSpec("home2", root=str(tmp_path / "h2")),
               SiteSpec("pod", root=str(tmp_path / "pod"),
                        nic_budget=pod_budget),
               SiteSpec("r1"), SiteSpec("r2")),
        links=(LinkSpec("pod", "r1", latency_s=0.005),
               LinkSpec("pod", "r2", latency_s=0.015)),
        link=LinkModel(latency_s=HOME_LATENCY))
    fab = Fabric(spec)
    s1 = fab.login("alice", home="home1", site="pod",
                   replicas=ReplicaPolicy(sites=("r1",)))
    s2 = fab.login("bob", home="home2", site="pod",
                   replicas=ReplicaPolicy(sites=("r2",)))
    return fab, s1, s2


def test_two_users_one_fabric_are_isolated(tmp_path):
    from repro.core import AuthError
    fab, s1, s2 = two_user_fabric(tmp_path)
    assert fab.sessions == [s1, s2]
    assert s1.network is s2.network                     # shared topology
    s1.server.store.put(s1.token, "home/secret1", b"a" * 1000)
    s2.server.store.put(s2.token, "home/secret2", b"b" * 1000)
    s1.replicas.resync()
    s2.replicas.resync()
    # foreign tokens are worthless at the other user's home AND replicas
    with pytest.raises(AuthError):
        s2.server.store.get(s1.token, "home/secret2")
    with pytest.raises(AuthError):
        s1.server.store.get(s2.token, "home/secret1")
    for other, sess in ((s2, s1), (s1, s2)):
        for rep in other.replicas.replicas.values():
            with pytest.raises((AuthError, FileNotFoundError)):
                rep.store.get(sess.token, "home/secret%d" %
                              (2 if other is s2 else 1))
    # each client reads only its own namespace
    with s1.client.open("home/secret1") as f:
        assert f.read() == b"a" * 1000
    with pytest.raises(FileNotFoundError):
        s1.client.open("home/secret2")


def test_shared_nic_budget_charges_both_sessions(tmp_path):
    """The pod's NIC budget is one shared resource: both users' traffic
    serializes through it, so the two-user drain is bounded below by
    total-bytes / budget — and strictly slower than an uncapped pod."""
    budget = 10 * MB
    nbytes = 2 * MB

    def drain_two(pod_budget):
        fab, s1, s2 = two_user_fabric(tmp_path if pod_budget is None
                                      else tmp_path / "cap",
                                      pod_budget=pod_budget)
        net = fab.network
        for s, name in ((s1, "alice"), (s2, "bob")):
            with s.client.open(f"home/out/{name}.dat", "w") as f:
                f.write(b"Z" * nbytes)
        c0 = net.clock
        s1.client.sync()
        s2.client.sync()
        net.drain()
        return net.clock - c0

    capped = drain_two(budget)
    uncapped = drain_two(None)
    assert capped >= 2 * nbytes / budget                # conservation
    assert capped > uncapped


def test_attach_joins_existing_session(tmp_path):
    """A second reader attaches to the owner's home space on its own
    token; replica fills and privacy both hold."""
    from repro.core import AuthError
    fab, s1, s2 = two_user_fabric(tmp_path)
    s1.server.store.put(s1.token, "home/shared.bin", b"s" * (1 * MB))
    s1.replicas.resync()
    reader = fab.attach(s1, "pod", owner="carol",
                        mounts=(MountSpec("home/"),))
    with reader.open("home/shared.bin") as f:
        assert f.read() == b"s" * (1 * MB)
    assert reader.cache.fills_from == {"r1": 1}         # rides the fabric
    # carol's token is scoped to alice's store, not bob's
    tok = reader.mounts["home/"].token
    assert tok != s1.token
    with pytest.raises(AuthError):
        s2.server.store.get(tok, "home/secret2")


# ---- remount restores the MountSpec ---------------------------------------

def localized_session(tmp_path):
    fab = Fabric(star_spec(tmp_path, "rm"))
    return fab.login("sci", mounts=[
        MountSpec("home/", ("home/scratch/raw/",))])


def test_bare_remount_restores_localized_subprefixes(tmp_path):
    """Regression: remount() used to silently drop the localized list,
    silently turning never-ships-home scratch into write-behind."""
    s = localized_session(tmp_path)
    s.server.crash()
    s.remount()
    with s.client.open("home/scratch/raw/dump.bin", "w") as f:
        f.write(b"\x00" * 10_000)
    assert s.client.oplog.pending() == []               # still localized
    assert s.client.mounts["home/"].localized == ["home/scratch/raw/"]


def test_bare_remount_without_mount_specs_reads_live_mounts(tmp_path):
    """A Session built outside Fabric.login carries no mount_specs; the
    live Mounts still know their localized lists and a bare remount
    must honor them."""
    s = localized_session(tmp_path)
    s.mount_specs.clear()                  # pre-spec construction pattern
    s.remount()
    assert s.client.mounts["home/"].localized == ["home/scratch/raw/"]


def test_bare_remount_covers_mounts_added_after_login(tmp_path):
    """A mount added directly via client.mount() after login must be
    re-mounted too — a bare remount that skipped it would leave the
    live Mount holding a token the crash revoked."""
    s = localized_session(tmp_path)
    s.client.mount("proj/", s.server.endpoint.name, s.server.store,
                   s.token, localized=["proj/tmp/"])
    s.server.store.put(s.token, "proj/x", b"x")
    s.server.crash()
    s.remount()
    assert s.client.mounts["proj/"].token == s.token     # fresh token
    assert s.client.mounts["proj/"].localized == ["proj/tmp/"]
    with s.client.open("proj/x") as f:                   # usable end to end
        assert f.read() == b"x"
    assert s.client.mounts["home/"].localized == ["home/scratch/raw/"]


def test_remount_prefix_without_stored_spec_reads_live_mount(tmp_path):
    """remount(prefix) on a Session with no stored MountSpec must fall
    back to the live Mount's localized list, same as bare remount()."""
    s = localized_session(tmp_path)
    s.mount_specs.clear()
    s.remount("home/")
    assert s.client.mounts["home/"].localized == ["home/scratch/raw/"]
    assert s.mount_specs["home/"].localized == ("home/scratch/raw/",)


def test_remount_single_prefix_keeps_its_spec(tmp_path):
    s = localized_session(tmp_path)
    s.remount("home/")
    assert s.client.mounts["home/"].localized == ["home/scratch/raw/"]


def test_remount_localized_override_updates_spec(tmp_path):
    s = localized_session(tmp_path)
    s.remount("home/", localized=["home/tmp/"])
    assert s.client.mounts["home/"].localized == ["home/tmp/"]
    assert s.mount_specs["home/"].localized == ("home/tmp/",)
    s.remount()                                         # override sticks
    assert s.client.mounts["home/"].localized == ["home/tmp/"]


def test_bare_remount_leaves_foreign_mounts_untouched(tmp_path):
    """alice's client also mounts bob's store (the shared-project
    pattern): alice's remount must not rebind that mount onto her own
    store — bob's server did not crash and her token is worthless
    there."""
    fab, alice, bob = two_user_fabric(tmp_path)
    bob.server.store.put(bob.token, "proj/shared", b"b" * 1000)
    alice.client.mount("proj/", bob.server.endpoint.name,
                       bob.server.store, bob.token)
    alice.server.crash()
    alice.remount()
    m = alice.client.mounts["proj/"]
    assert m.store is bob.server.store            # still bob's
    assert m.token == bob.token                   # bob's token survives
    with alice.client.open("proj/shared") as f:   # cold read still works
        assert f.read() == b"b" * 1000
    with pytest.raises(ValueError, match="another home store"):
        alice.remount("proj/")                    # explicit ask is an error


def test_bare_remount_respects_spec_prefix_repointed_to_foreign_store(
        tmp_path):
    """A spec-tracked prefix later re-pointed at a foreign store via
    client.mount must NOT be yanked back onto the session's own store
    by a bare remount — the live mount wins."""
    fab, alice, bob = two_user_fabric(tmp_path)
    bob.server.store.put(bob.token, "home/bobs", b"b" * 500)
    alice.client.mount("home/", bob.server.endpoint.name,
                       bob.server.store, bob.token)
    alice.server.crash()
    alice.remount()
    assert alice.client.mounts["home/"].store is bob.server.store


def test_remount_single_legacy_prefix_restores_field_for_field(tmp_path):
    """remount(prefix) on a legacy no-slash mount (accepted by
    client.mount, rejected by MountSpec) restores it raw instead of
    raising — targeted recovery must not require the all-mounts path."""
    s = localized_session(tmp_path)
    s.client.mount("raw", s.server.endpoint.name, s.server.store,
                   s.token, localized=["raw/tmp/"])
    s.server.crash()
    s.remount("raw")
    assert s.client.mounts["raw"].token == s.token
    assert s.client.mounts["raw"].localized == ["raw/tmp/"]
    assert "raw" not in s.mount_specs             # unvalidatable: unrecorded


def test_remount_validation_is_atomic(tmp_path):
    """A rejected remount must leave the session untouched — the old
    order rotated the token first, bricking every live mount when a
    legacy (unvalidatable) prefix aborted the loop mid-way."""
    s = localized_session(tmp_path)
    token0 = s.token
    with pytest.raises(ValueError, match="end with"):
        s.remount("noslash", localized=["noslash/x/"])
    assert s.token == token0                      # token not rotated
    with s.client.open("home/a", "w") as f:       # session fully usable
        f.write(b"a")
    # a legacy no-slash mount added directly survives a bare remount
    s.client.mount("raw", s.server.endpoint.name, s.server.store,
                   s.token, localized=["raw/tmp/"])
    s.server.crash()
    s.remount()
    assert s.client.mounts["raw"].token == s.token
    assert s.client.mounts["raw"].localized == ["raw/tmp/"]


def test_remount_does_not_leak_store_subscriptions(tmp_path):
    """Re-mounting replaces the notification channel; the old channel's
    store subscription must go with it, or every put() feeds an
    orphaned pending list forever."""
    s = localized_session(tmp_path)
    n0 = len(s.server.store._subscribers)
    for _ in range(3):
        s.remount()
    assert len(s.server.store._subscribers) == n0
    s.remount("home/", localized=["home/tmp/"])
    assert len(s.server.store._subscribers) == n0


def test_remount_preserves_side_mount_replica_wiring(tmp_path):
    """A side mount explicitly created with replicas=None must not gain
    the session's ReplicaSet on remount — either spelling."""
    fab = Fabric(star_spec(tmp_path, "sidew", replicas=("r1",)))
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    s.client.mount("side/", s.server.endpoint.name, s.server.store,
                   s.token, localized=None, replicas=None)
    s.remount("side/")
    assert s.client.mounts["side/"].replicas is None
    s.remount()
    assert s.client.mounts["side/"].replicas is None
    assert s.client.mounts["home/"].replicas is s.replicas


def test_remount_localized_without_prefix_rejected(tmp_path):
    s = localized_session(tmp_path)
    with pytest.raises(ValueError, match="prefix"):
        s.remount(localized=["home/x/"])


def test_remount_reauthenticates_and_reattaches(tmp_path):
    fab = Fabric(star_spec(tmp_path, "rma", replicas=("r1",)))
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    s.server.store.put(s.token, "home/x", b"x")
    old_token = s.token
    s.server.crash()                       # drops token + subscriptions
    s.remount()
    assert s.token != old_token
    assert s.replicas.token == s.token
    with s.client.open("home/x") as f:     # fresh token works end to end
        assert f.read() == b"x"


def test_shim_empty_mounts_dict_gets_default_mount(tmp_path):
    """Pre-refactor `mounts or {...}` gave a falsy empty dict the
    default home/ mount; the shim must preserve that."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        s = ussh_login("sci", Network(), str(tmp_path / "h"),
                       str(tmp_path / "s"), mounts={})
    assert list(s.client.mounts) == ["home/"]
    assert s.mount_specs == {"home/": MountSpec("home/")}


def test_star_constructor_matches_handrolled_spec(tmp_path):
    built = FabricSpec.star(
        str(tmp_path / "h-star"), str(tmp_path / "s-star"),
        replica_latencies=dict(REPLICAS),
        nic_budgets={"home": 100 * MB, "elsewhere": 10 * MB},
        link=LinkModel(latency_s=HOME_LATENCY))
    hand = star_spec(tmp_path, "star", replicas=tuple(REPLICAS),
                     budgets={"home": 100 * MB})
    hand = FabricSpec(sites=hand.sites + (SiteSpec("elsewhere",
                                                   nic_budget=10 * MB),),
                      links=hand.links, link=hand.link)
    assert built == hand


def test_star_merges_budget_onto_grafted_extra_site(tmp_path):
    """A NIC budget naming a site that arrives via extra_sites lands on
    that site instead of colliding as a duplicate budget-only site."""
    spec = FabricSpec.star(
        str(tmp_path / "h-g"), str(tmp_path / "s-g"),
        nic_budgets={"c0": 10 * MB},
        extra_sites=(SiteSpec("c0"), SiteSpec("c1")))
    assert spec.site("c0").nic_budget == 10 * MB
    assert spec.site("c1").nic_budget is None


# ---- deprecation -----------------------------------------------------------

def test_ussh_login_warns_exactly_once_with_migration_hint(tmp_path):
    session_mod._DEPRECATION_WARNED = False
    try:
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            net = Network()
            ussh_login("sci", net, str(tmp_path / "h1"), str(tmp_path / "s1"))
            ussh_login("sci2", net, str(tmp_path / "h2"),
                       str(tmp_path / "s2"))
        deps = [w for w in caught
                if issubclass(w.category, DeprecationWarning)]
        assert len(deps) == 1                  # once per process, not call
        msg = str(deps[0].message)
        assert "FabricSpec" in msg and "docs/fabric.md" in msg
    finally:
        session_mod._DEPRECATION_WARNED = True
