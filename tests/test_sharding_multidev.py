"""Sharded lowering + collectives on a multi-device host platform.

These tests need >1 XLA host device, which must be configured BEFORE jax
initializes — so they run in a subprocess with XLA_FLAGS set.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(body: str, n_dev: int = 8) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (
            "--xla_force_host_platform_device_count={n_dev}")
        import sys
        sys.path.insert(0, {SRC!r})
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


def test_tiny_train_step_compiles_and_runs_on_2x2_mesh():
    out = _run("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs import get_tiny_config
        from repro.config import RunConfig, ShapeConfig, OptimConfig, ShardingConfig
        from repro.data.batches import make_batch
        from repro.launch.mesh import make_test_mesh
        from repro.models import init_params, param_axes
        from repro.optim import state_axes
        from repro.parallel.context import sharding_ctx
        from repro.parallel.sharding import make_ctx, tree_shardings, batch_shardings
        from repro.train.step import make_train_step, make_opt_state

        cfg = get_tiny_config('qwen3-8b').replace(remat='full')
        run = RunConfig(model=cfg, shape=ShapeConfig('t','train',16,4),
                        sharding=ShardingConfig(policy='fsdp'))
        mesh = make_test_mesh(2, 2)
        ctx = make_ctx(mesh, run.sharding)
        p = init_params(cfg, jax.random.PRNGKey(0))
        opt = make_opt_state(run, p)
        batch = make_batch(cfg, 4, 16)
        p_sh = tree_shardings(ctx, param_axes(cfg))
        o_sh = tree_shardings(ctx, state_axes(param_axes(cfg), run.optim))
        b_sh = batch_shardings(ctx, batch)
        p = jax.device_put(p, p_sh)
        opt = jax.device_put(opt, o_sh)
        batch = jax.device_put(batch, b_sh)
        with sharding_ctx(ctx):
            step = jax.jit(make_train_step(run),
                           in_shardings=(p_sh, o_sh, b_sh),
                           out_shardings=(p_sh, o_sh, None))
            p2, opt2, metrics = step(p, opt, batch)
        loss = float(metrics['loss'])
        assert loss == loss and loss > 0, loss
        print('SHARDED_OK', loss)
    """)
    assert "SHARDED_OK" in out


def test_sharded_loss_matches_single_device():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_tiny_config
        from repro.config import RunConfig, ShapeConfig, ShardingConfig
        from repro.data.batches import make_batch
        from repro.launch.mesh import make_test_mesh
        from repro.models import init_params, param_axes, loss_fn
        from repro.parallel.context import sharding_ctx
        from repro.parallel.sharding import make_ctx, tree_shardings, batch_shardings

        cfg = get_tiny_config('qwen3-moe-30b-a3b')
        p = init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, 4, 16)
        l0, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b))(p, batch)

        run = RunConfig(model=cfg, shape=ShapeConfig('t','train',16,4),
                        sharding=ShardingConfig(policy='fsdp'))
        mesh = make_test_mesh(2, 2)
        ctx = make_ctx(mesh, run.sharding)
        p_sh = tree_shardings(ctx, param_axes(cfg))
        b_sh = batch_shardings(ctx, batch)
        with sharding_ctx(ctx):
            l1, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b),
                            in_shardings=(p_sh, b_sh))(
                jax.device_put(p, p_sh), jax.device_put(batch, b_sh))
        err = abs(float(l0) - float(l1))
        assert err < 2e-2, (float(l0), float(l1))
        print('MATCH_OK', err)
    """)
    assert "MATCH_OK" in out


def test_multipod_mesh_axes_and_decode_lowering():
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_tiny_config
        from repro.config import RunConfig, ShapeConfig, ShardingConfig
        from repro.launch.mesh import make_test_mesh
        from repro.models import (init_params, param_axes, init_cache,
                                  cache_logical_axes, decode_step)
        from repro.parallel.context import sharding_ctx
        from repro.parallel.sharding import make_ctx, tree_shardings

        cfg = get_tiny_config('qwen3-8b').replace(param_dtype='bfloat16')
        mesh = make_test_mesh(2, 2, pods=2)
        assert mesh.axis_names == ('pod', 'data', 'model')
        ctx = make_ctx(mesh, ShardingConfig(policy='baseline'), decode=True)
        p = init_params(cfg, jax.random.PRNGKey(0))
        cache = init_cache(cfg, 4, 32)
        p_sh = tree_shardings(ctx, param_axes(cfg))
        c_sh = tree_shardings(ctx, cache_logical_axes(cfg))
        tok_sh = ctx.sharding(('batch', None))
        with sharding_ctx(ctx):
            fn = jax.jit(lambda p, t, c: decode_step(cfg, p, t, c),
                         in_shardings=(p_sh, tok_sh, c_sh),
                         out_shardings=(None, c_sh))
            lowered = fn.lower(
                jax.device_put(p, p_sh),
                jax.device_put(jnp.zeros((4,1), jnp.int32), tok_sh),
                jax.device_put(cache, c_sh))
            compiled = lowered.compile()
        print('DECODE_LOWER_OK', compiled.memory_analysis() is not None)
    """)
    assert "DECODE_LOWER_OK" in out


def test_hierarchical_psum_and_compressed_psum():
    out = _run("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.launch.mesh import make_test_mesh
        from repro.parallel.collectives import hierarchical_psum, compressed_psum

        mesh = make_test_mesh(2, 2, pods=2)
        x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

        def f(xs):
            return hierarchical_psum(xs, 'pod', 'data')

        y = shard_map(f, mesh=mesh, in_specs=P(('pod','data'), None),
                      out_specs=P(('pod','data'), None))(x)
        # psum over pod+data of each shard: every (pod,data) shard sums
        expect = jnp.tile(x.reshape(4, 2, 16).sum(0), (4, 1))
        np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                                   rtol=1e-6)

        def g(xs):
            return compressed_psum(xs, 'data')

        z = shard_map(g, mesh=mesh, in_specs=P(('pod','data'), None),
                      out_specs=P(('pod','data'), None))(x)
        assert z.shape == x.shape
        print('COLLECTIVES_OK')
    """)
    assert "COLLECTIVES_OK" in out
