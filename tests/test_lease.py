"""Lease manager: virtual-clock TTL expiry, renewal under partition,
localized vs remote locks, and the at-risk re-verification lifecycle.

The renewal-under-partition cases are regression tests for the
``renew_all`` bug where a ``DisconnectedError`` mid-loop ``break``-ed out
leaving every unprobed lease in ``held`` as if renewed — the client kept
acting as lock holder after the server-side TTL expired.
"""
import pytest

from repro.core import (
    DisconnectedError, Endpoint, Fabric, FabricSpec, LeaseManager,
    LinkModel, MountSpec, Network, ReplicaPolicy,
)
from repro.core.store import HomeStore
from repro.core.transport import respond


def make_store(tmp_path, network, name="home"):
    return HomeStore(str(tmp_path / name), endpoint=network.endpoint(name))


def authed(store):
    return store.authenticate(lambda ch: respond(store.keyphrase, ch))


@pytest.fixture()
def wired(tmp_path):
    net = Network(link=LinkModel(latency_s=0.030))
    Endpoint("site", net)
    Endpoint("home", net)
    store = make_store(tmp_path, net)
    lm = LeaseManager(net, "site", "home", store, owner="alice",
                      token=authed(store), ttl=30.0)
    return net, store, lm


# ---- virtual-clock TTL expiry ----------------------------------------------

def test_ttl_expiry_frees_the_lock(wired):
    net, store, lm = wired
    assert lm.acquire("home/shared.dat")
    assert store.lock_owner("home/shared.dat", net.clock) == "alice"
    net.advance(lm.ttl + 1)
    # expired server-side: another owner can take it
    assert store.lock_owner("home/shared.dat", net.clock) is None
    bob = LeaseManager(net, "site", "home", store, owner="bob",
                      token=authed(store), ttl=30.0)
    assert bob.acquire("home/shared.dat")
    # alice's renewal now honestly reports the loss
    assert lm.renew_all() == 0
    assert "home/shared.dat" not in lm.held


def test_renewal_extends_the_ttl(wired):
    net, store, lm = wired
    assert lm.acquire("home/a")
    for _ in range(4):
        net.advance(lm.ttl / 2)
        assert lm.renew_all() == 1
    # 2x TTL elapsed but renewals kept it alive
    assert store.lock_owner("home/a", net.clock) == "alice"


# ---- renewal under partition (the renew_all bugfix) ------------------------

def test_partition_marks_unprobed_leases_at_risk(wired):
    net, store, lm = wired
    for i in range(4):
        assert lm.acquire(f"home/f{i}")
    net.partition("site", "home")
    assert lm.renew_all() == 0
    # nothing silently "renewed": every unprobed lease is tracked at risk
    assert lm.at_risk == {f"home/f{i}" for i in range(4)}
    assert lm.held == {f"home/f{i}" for i in range(4)}
    assert lm.renew_interruptions == 1


def test_mid_loop_partition_marks_only_the_remainder(wired):
    net, store, lm = wired
    for i in range(4):
        assert lm.acquire(f"home/f{i}")
    orig = net.transfer
    calls = {"n": 0}

    def die_after_two(src, dst, method, *a, **kw):
        if method == "lock_renew":
            calls["n"] += 1
            if calls["n"] > 2:
                raise DisconnectedError("mid-renewal drop")
        return orig(src, dst, method, *a, **kw)

    net.transfer = die_after_two
    try:
        assert lm.renew_all() == 2           # probes f0, f1 landed
    finally:
        net.transfer = orig
    assert lm.at_risk == {"home/f2", "home/f3"}
    assert lm.held == {f"home/f{i}" for i in range(4)}


def test_reverify_drops_leases_the_server_expired(wired):
    net, store, lm = wired
    for i in range(3):
        assert lm.acquire(f"home/f{i}")
    net.partition("site", "home")
    lm.renew_all()
    assert len(lm.at_risk) == 3
    # while partitioned, the server TTL runs out and bob takes f1
    net.advance(lm.ttl + 1)
    bob = LeaseManager(net, "home", "home", store, owner="bob",
                      token=authed(store), ttl=30.0)
    assert bob.acquire("home/f1")
    net.heal("site", "home")
    kept, dropped = lm.reverify_at_risk()
    # f0/f2 were expired-but-unclaimed: renew re-establishes them;
    # f1 now belongs to bob and is dropped — alice never acts on it again
    assert (kept, dropped) == (2, 1)
    assert lm.held == {"home/f0", "home/f2"}
    assert lm.at_risk == set()
    assert store.lock_owner("home/f1", net.clock) == "bob"


def test_reverify_while_still_partitioned_keeps_everything_at_risk(wired):
    net, store, lm = wired
    assert lm.acquire("home/x")
    net.partition("site", "home")
    lm.renew_all()
    assert lm.reverify_at_risk() == (0, 0)
    assert lm.at_risk == {"home/x"}


def test_connected_release_clears_at_risk_tracking(wired):
    net, store, lm = wired
    assert lm.acquire("home/x")
    lm.at_risk.add("home/x")    # e.g. left over from a healed partition
    lm.release("home/x")
    assert lm.at_risk == set()
    assert lm.held == set()
    assert lm.pending_release == set()
    assert store.lock_owner("home/x", net.clock) is None


def test_partitioned_release_is_remembered_and_finished_on_heal(wired):
    # the release() counterpart of the renew_all at-risk fix: a release
    # the partition swallowed used to vanish from the client's books
    # while the server kept honoring the lock until TTL expiry
    net, store, lm = wired
    assert lm.acquire("home/x")
    net.partition("site", "home")
    lm.release("home/x")
    assert lm.held == set()                      # we no longer act as holder
    assert lm.pending_release == {"home/x"}      # ...but the server does
    assert lm.at_risk == {"home/x"}
    assert store.lock_owner("home/x", net.clock) == "alice"
    # still partitioned: reverify cannot reach the server, stays pending
    assert lm.reverify_at_risk() == (0, 0)
    assert lm.pending_release == {"home/x"}
    net.heal("site", "home")
    kept, dropped = lm.reverify_at_risk()
    assert (kept, dropped) == (0, 1)
    assert lm.pending_release == set() and lm.at_risk == set()
    # the server-side lock went away NOW, not at TTL expiry: another
    # writer can take it immediately
    assert store.lock_owner("home/x", net.clock) is None
    bob = LeaseManager(net, "site", "home", store, owner="bob",
                       token=authed(store), ttl=30.0)
    assert bob.acquire("home/x")


# ---- localized vs remote locks ---------------------------------------------

def test_localized_lock_never_touches_the_wire(tmp_path):
    fab = Fabric(FabricSpec.star(str(tmp_path / "h"), str(tmp_path / "s")))
    s = fab.login("sci", mounts=[MountSpec("home/",
                                           localized=("home/scratch/",))])
    rpc0 = s.network.rpc_count
    assert s.client.lock("home/scratch/tmpfile")
    s.client.unlock("home/scratch/tmpfile")
    assert s.network.rpc_count == rpc0
    lm = s.client.leases["home/"]
    assert lm.local_locks == set() and lm.held == set()


def test_remote_lock_rides_the_wan_and_survives_renewal(tmp_path):
    fab = Fabric(FabricSpec.star(str(tmp_path / "h"), str(tmp_path / "s")))
    s = fab.login("sci")
    rpc0 = s.network.rpc_count
    assert s.client.lock("home/data/shared")
    assert s.network.rpc_count == rpc0 + 1
    lm = s.client.leases["home/"]
    assert lm.held == {"home/data/shared"}
    assert lm.renew_all() == 1


# ---- client-level reconnect reverification ---------------------------------

def test_reconnect_reverifies_at_risk_leases(tmp_path):
    fab = Fabric(FabricSpec.star(str(tmp_path / "h"), str(tmp_path / "s"),
                                 replica_latencies={"r1": 0.005}))
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    assert s.client.lock("home/data/shared")
    lm = s.client.leases["home/"]
    net = s.network
    net.partition("site", "home")
    lm.renew_all()
    assert lm.at_risk == {"home/data/shared"}
    net.heal("site", "home")
    s.client.reconnect()
    assert lm.at_risk == set()
    assert lm.held == {"home/data/shared"}
    assert s.server.store.lock_owner("home/data/shared", net.clock) == "sci"


def test_remount_carries_leases_over_at_risk(tmp_path):
    """A re-mount rotates the token; held locks survive AT RISK until
    re-verified rather than being silently forgotten."""
    fab = Fabric(FabricSpec.star(str(tmp_path / "h"), str(tmp_path / "s")))
    s = fab.login("sci")
    assert s.client.lock("home/data/shared")
    s.server.crash()
    s.remount()
    lm = s.client.leases["home/"]
    assert lm.held == {"home/data/shared"}
    assert "home/data/shared" in lm.at_risk
    assert lm.token == s.token          # rotated token, not the stale one
    kept, dropped = lm.reverify_at_risk()
    assert (kept, dropped) == (1, 0)
    assert store_owner(s) == "sci"


def store_owner(s):
    return s.server.store.lock_owner("home/data/shared", s.network.clock)
