"""Trainer loop (fault injection, restart, straggler) + serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Fabric, FabricSpec, MountSpec
from repro.config import RunConfig, ShapeConfig, OptimConfig
from repro.configs import get_tiny_config
from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticCorpus, DataPipeline
from repro.models import init_params
from repro.serve.engine import ServeEngine, Request
from repro.train import Trainer, FaultMonitor, FaultEvent
from repro.train.step import make_train_step, make_opt_state


def _mk_trainer(tmp_path, *, monitor=None, micro=1, steps_total=60,
                grad_compress="none"):
    s = Fabric(FabricSpec.star(str(tmp_path / "h"), str(tmp_path / "s"))) \
        .login("sci", mounts=[MountSpec("home/", ("home/scratch/",))])
    cfg = get_tiny_config("qwen3-4b")
    run = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 32, 4),
                    optim=OptimConfig(lr=1e-3, warmup_steps=5,
                                      total_steps=steps_total,
                                      grad_compress=grad_compress),
                    microbatches=micro)
    corpus = SyntheticCorpus(s.client, "home/data", seed=0,
                             vocab=cfg.vocab_size, shard_tokens=4096)
    corpus.materialize(2)
    pipe = DataPipeline(s.client, "home/data", cfg, batch=4, seq=32,
                        n_shards=2)
    ckpt = CheckpointManager(s.client, "home/ckpt")
    return Trainer(run, pipe, ckpt, monitor=monitor, ckpt_every=4), s


def test_loss_decreases(tmp_path):
    tr, _ = _mk_trainer(tmp_path)
    res = tr.train(12)
    assert res.losses[-1] < res.losses[0]


def test_crash_restart_resumes_from_checkpoint(tmp_path):
    mon = FaultMonitor(n_workers=4, schedule=[
        FaultEvent(step=6, worker=2, kind="crash")])
    tr, s = _mk_trainer(tmp_path, monitor=mon)
    res = tr.train(10)
    assert res.restarts == 1
    assert tr.step == 10
    assert res.checkpoints   # checkpoints were published


def test_straggler_dropped_then_rejoins(tmp_path):
    mon = FaultMonitor(n_workers=4, schedule=[
        FaultEvent(step=3, worker=1, kind="straggle", duration=2)])
    tr, _ = _mk_trainer(tmp_path, monitor=mon)
    res = tr.train(8)
    assert mon.dropped_syncs == 2      # bounded staleness, no restart
    assert res.restarts == 0


def test_too_stale_straggler_forces_remesh(tmp_path):
    mon = FaultMonitor(n_workers=2, max_staleness=1, schedule=[
        FaultEvent(step=5, worker=0, kind="straggle", duration=10)])
    tr, _ = _mk_trainer(tmp_path, monitor=mon)
    res = tr.train(8)
    assert res.restarts >= 1


def test_cold_restore_reproduces_params(tmp_path):
    tr, s = _mk_trainer(tmp_path)
    tr.train(8)
    tr2 = Trainer(tr.run, tr.pipeline, tr.ckpt)
    tr2.initialize()
    assert tr2.restore_latest()
    assert tr2.step == 8
    for a, b in zip(jax.tree.leaves(tr.params), jax.tree.leaves(tr2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_grad_compression_trains(tmp_path):
    """EF-int8 compression must run end-to-end and keep the compressed
    update aligned with the true gradient (early-step losses are noisy, so
    direction — not a 10-step loss delta — is the invariant)."""
    import jax.numpy as jnp
    from repro.optim import init_error, compress_decompress
    tr, _ = _mk_trainer(tmp_path, grad_compress="int8")
    res = tr.train(10)
    assert all(np.isfinite(res.losses))
    assert "ef_error" in tr.opt_state
    # direction check on a fresh gradient-sized tree
    key = jax.random.PRNGKey(3)
    g = {"w": jax.random.normal(key, (512,))}
    deq, _ = compress_decompress(g, init_error(g))
    cos = float(jnp.sum(g["w"] * deq["w"])
                / (jnp.linalg.norm(g["w"]) * jnp.linalg.norm(deq["w"])))
    assert cos > 0.999, cos


def test_microbatching_matches_full_batch_loss():
    cfg = get_tiny_config("qwen3-8b")
    run1 = RunConfig(model=cfg, shape=ShapeConfig("t", "train", 16, 4),
                     optim=OptimConfig(lr=0.0, grad_clip=1e9),
                     microbatches=1)
    run4 = dataclasses.replace(run1, microbatches=4)
    from repro.data.batches import make_batch
    batch = make_batch(cfg, 4, 16)
    p = init_params(cfg, jax.random.PRNGKey(0))
    s1 = make_opt_state(run1, p)
    s4 = make_opt_state(run4, p)
    p1, _, m1 = jax.jit(make_train_step(run1))(p, s1, batch)
    p4, _, m4 = jax.jit(make_train_step(run4))(p, s4, batch)
    # average loss over microbatches == full-batch loss (same tokens)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-2
    del p1, p4


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_continuous_batching_matches_single_slot():
    cfg = get_tiny_config("qwen3-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=3, max_len=64)
    prompts = [[1, 2, 3, 4, 5], [7, 8, 9], [10, 11, 12, 13, 14, 15, 16],
               [20, 21]]
    for i, pr in enumerate(prompts):
        eng.add_request(Request(rid=i, prompt=pr, max_new_tokens=5))
    eng.run_until_done()
    for i, pr in enumerate(prompts):
        solo = ServeEngine(cfg, params, slots=1, max_len=64)
        solo.add_request(Request(rid=0, prompt=pr, max_new_tokens=5))
        solo.run_until_done()
        assert eng.requests[i].output == solo.requests[0].output, i


def test_engine_reuses_slots():
    cfg = get_tiny_config("qwen3-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, slots=2, max_len=64)
    for i in range(5):
        eng.add_request(Request(rid=i, prompt=[1 + i, 2 + i],
                                max_new_tokens=3))
    eng.run_until_done()
    assert all(eng.requests[i].done for i in range(5))
    assert eng.tokens_generated >= 5 * 2   # decode tokens (prefill emits 1st)
