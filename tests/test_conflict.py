"""Concurrent-writer safety: vector-timestamp algebra, vts persistence
in the WAL, write leases over the replica set, conflict detection with
deterministic last-writer-wins, and the ConflictRecord lifecycle.

The two-writer scenarios build real concurrency through the fabric:
``login`` + ``attach`` share one home store and one replica set, so two
sessions writing the same path around a home outage produce branches
that are *semantically* concurrent even though their version numbers
never collide.
"""
import dataclasses
import json
import os

import pytest

from repro.core import (
    ConflictRecord, Fabric, FabricSpec, LinkModel, MaintenanceSpec,
    MetaOpQueue, MountSpec, ReplicaPolicy, SiteSpec, WriteLeaseSpec,
)
from repro.core.oplog import (
    OpRecord, vts_concurrent, vts_dominates, vts_lww_key, vts_merge,
)

HOME_LATENCY = 0.060


# ---- vts algebra ------------------------------------------------------------

def test_vts_merge_is_pointwise_max():
    assert vts_merge({"a": 2, "b": 1}, {"b": 3, "c": 1}) == \
        {"a": 2, "b": 3, "c": 1}
    assert vts_merge(None, {"a": 1}) == {"a": 1}
    assert vts_merge({}, None) == {}


def test_vts_dominates_and_concurrent():
    assert vts_dominates({"a": 2, "b": 1}, {"a": 1})
    assert vts_dominates({"a": 1}, {"a": 1})            # equality dominates
    assert not vts_dominates({"a": 1}, {"a": 2})
    assert vts_dominates({"a": 1}, {})                  # empty/legacy
    assert vts_dominates({}, None)
    assert not vts_dominates({}, {"a": 1})
    assert vts_concurrent({"a": 1}, {"b": 1})
    assert not vts_concurrent({"a": 2, "b": 1}, {"a": 1})


def test_vts_lww_key_totally_orders_concurrent_branches():
    # more causal events wins first...
    assert vts_lww_key({"a": 1, "b": 1}) > vts_lww_key({"c": 1})
    # ...then the lexicographically greatest writer set breaks the tie
    assert vts_lww_key({"sci": 1}) > vts_lww_key({"bob": 1})
    # two concurrent branches can never compare equal: equal sums AND
    # equal sorted items would make them the same dict
    assert vts_lww_key({"a": 2}) != vts_lww_key({"b": 2})


# ---- WAL persistence --------------------------------------------------------

def test_vts_rides_the_wal_and_survives_recovery(tmp_path):
    q = MetaOpQueue(str(tmp_path / "oplog"))
    rec = q.append("store", "home/x", b"data")
    rec.vts = {"sci": 3, "bob": 1}
    q.mark_acked(rec, "r1", version=7)
    [back] = MetaOpQueue(str(tmp_path / "oplog")).scan()
    assert back.vts == {"sci": 3, "bob": 1}
    assert back.version == 7 and back.acked == ["r1"]


def test_legacy_wal_lines_without_vts_load_as_none(tmp_path):
    root = tmp_path / "oplog"
    q = MetaOpQueue(str(root))
    # a WAL line written before vts existed has no such key at all
    legacy = {"seq": 1, "op": "store", "path": "home/old",
              "payload_file": None, "status": "pending", "acked": [],
              "version": None}
    with open(q.wal_path, "a") as f:
        f.write(json.dumps(legacy) + "\n")
    [back] = MetaOpQueue(str(root)).scan()
    assert back.vts is None


# ---- ConflictRecord lifecycle ----------------------------------------------

def test_conflict_record_resolve_validates_and_is_one_shot():
    applied = []
    rec = ConflictRecord(
        path="home/x", seq=1, owner="sci",
        ours_vts={"sci": 1}, theirs_vts={"bob": 1}, winner="ours",
        ours_data=b"ours", theirs_data=b"theirs", detected_at=1.0,
        _apply=applied.append)
    with pytest.raises(ValueError):
        rec.resolve("coin-flip")
    rec.resolve("theirs")
    assert applied == [b"theirs"]
    assert rec.resolved and rec.resolution == "theirs"
    with pytest.raises(RuntimeError):
        rec.resolve("ours")


# ---- fabric helpers ---------------------------------------------------------

def two_writer_fab(tmp_path, *, write_lease=None, maintenance=None):
    spec = FabricSpec.star(
        str(tmp_path / "home"), str(tmp_path / "site"),
        replica_latencies={"r1": 0.005, "r2": 0.015},
        link=LinkModel(latency_s=HOME_LATENCY),
        extra_sites=(SiteSpec("site2", root=str(tmp_path / "site2")),))
    if maintenance is not None:
        spec = dataclasses.replace(spec, maintenance=maintenance)
    fab = Fabric(spec)
    s = fab.login("sci", replicas=ReplicaPolicy(
        sites=("r1", "r2"), write_quorum="majority",
        write_lease=write_lease))
    bob = fab.attach(s, "site2", owner="bob", mounts=[MountSpec("home/")])
    return fab, s, bob


PATH = "home/shared/doc.bin"
SCI_BYTES = b"S" * 200_000
BOB_BYTES = b"B" * 180_000


# ---- write leases on the replica set ---------------------------------------

def test_write_lease_acquire_contend_rollback_and_release(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path,
                                 write_lease=WriteLeaseSpec(ttl_s=10.0))
    rset, net = s.replicas, s.network
    assert rset.acquire_write_lease("site", PATH, "write:sci") is True
    for rep in rset.replicas.values():
        assert rep.store.lock_owner(PATH, net.clock) == "write:sci"
    # a second writer contends and leaves NO partial grants behind
    assert rset.acquire_write_lease("site2", PATH, "write:bob") is False
    for rep in rset.replicas.values():
        assert rep.store.lock_owner(PATH, net.clock) == "write:sci"
    assert rset.lease_acquired == 1 and rset.lease_contended == 1
    assert rset.release_write_lease("site", PATH, "write:sci") == 2
    # releasing when holding nothing is wire-free
    rpc0 = net.rpc_count
    assert rset.release_write_lease("site", PATH, "write:sci") == 0
    assert net.rpc_count == rpc0
    # now bob can take it
    assert rset.acquire_write_lease("site2", PATH, "write:bob") is True


def test_write_lease_unavailable_under_full_partition(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path,
                                 write_lease=WriteLeaseSpec(ttl_s=10.0))
    for r in ("r1", "r2"):
        s.network.partition("site", r)
    assert s.replicas.acquire_write_lease("site", PATH, "write:sci") is None
    assert s.replicas.lease_unavailable == 1


def test_write_lease_spec_validates():
    with pytest.raises(ValueError):
        WriteLeaseSpec(ttl_s=0.0)


# ---- concurrent branches: detect, LWW, preserve -----------------------------

def _divergent_write(fab, s, bob):
    """sci quorum-writes around a dead home while bob writes the same
    path straight at the (bob-reachable) home: two branches that know
    nothing of each other."""
    net = s.network
    net.partition("site", "home")              # sci cut off from home only
    with s.client.open(PATH, "w") as f:
        f.write(SCI_BYTES)
    assert s.client.pump() == 1                # parked at quorum (r1+r2)
    [rec] = s.client.oplog.unreconciled()
    assert rec.vts == {"sci": 1}
    with bob.open(PATH, "w") as f:
        f.write(BOB_BYTES)
    assert bob.pump() == 1                     # lands at home, vts {bob:1}
    net.heal("site", "home")
    return rec


def test_concurrent_branches_conflict_never_silently_clobber(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path)
    rec = _divergent_write(fab, s, bob)
    assert s.client.reconcile() == 1
    [c] = s.client.conflicts
    assert (c.path, c.owner) == (PATH, "sci")
    assert c.ours_vts == {"sci": 1} and c.theirs_vts == {"bob": 1}
    # deterministic LWW: equal causal mass, 'sci' > 'bob' lexically
    assert c.winner == "ours"
    assert c.ours_data == SCI_BYTES and c.theirs_data == BOB_BYTES
    # the winner's bytes land at home PAST both branches, and the merged
    # frontier covers them both
    data, st = s.server.store.get(s.token, PATH)
    assert data == SCI_BYTES
    assert st.version > rec.version
    assert s.server.store.vts_of(PATH) == {"sci": 1, "bob": 1}
    assert s.client.oplog.unreconciled() == []
    # anti-entropy converges the replicas onto the resolved branch
    s.replicas.resync()
    for rep in s.replicas.replicas.values():
        assert rep.store.get(rep.token, PATH)[0] == SCI_BYTES


def test_operator_resolve_overrides_the_lww_pick(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path)
    _divergent_write(fab, s, bob)
    s.client.reconcile()
    [c] = s.client.conflicts
    v0 = s.server.store.stat_unchecked(PATH).version
    c.resolve("theirs")                        # operator prefers bob's
    data, st = s.server.store.get(s.token, PATH)
    assert data == BOB_BYTES and st.version == v0 + 1
    assert s.server.store.vts_of(PATH) == {"sci": 1, "bob": 1}
    with pytest.raises(RuntimeError):
        c.resolve("ours")


def test_conflicts_surface_on_the_maintenance_report(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path, maintenance=MaintenanceSpec())
    _divergent_write(fab, s, bob)
    s.client.reconcile()
    r = fab.maintenance_report()
    assert r.conflicts == 1
    [c] = r.conflict_records
    assert isinstance(c, ConflictRecord) and c.path == PATH


def test_reconcile_order_is_irrelevant_exactly_one_conflict(tmp_path):
    """Whichever side reconciles first, the outcome is one ConflictRecord
    and the same final bytes — the branch that loses the race discovers
    it is dominated and retires quietly."""
    fab, s, bob = two_writer_fab(tmp_path)
    rec = _divergent_write(fab, s, bob)
    # bob has nothing parked (his write landed connected), so "bob
    # first" is a no-op reconcile; sci then detects the conflict
    assert bob.reconcile() == 0
    assert s.client.reconcile() == 1
    assert len(s.client.conflicts) == 1
    # a second reconcile pass finds nothing new
    assert s.client.reconcile() == 0
    assert len(s.client.conflicts) == 1


def test_superseded_branch_retires_without_fanning_stale_bytes(tmp_path):
    """When home's causal history already covers a parked branch (the
    writer's own later write landed first), reconcile retires it quietly
    — no conflict, no stale fan-out."""
    fab, s, bob = two_writer_fab(tmp_path)
    net = s.network
    net.partition("site", "home")
    with s.client.open(PATH, "w") as f:
        f.write(b"old" * 1000)
    assert s.client.pump() == 1                # parked at quorum
    net.heal("site", "home")
    with s.client.open(PATH, "w") as f:
        f.write(b"new" * 1000)
    assert s.client.pump() == 1                # lands at home, supersedes
    assert s.client.reconcile() == 0           # parked record was retired
    assert s.client.conflicts == []
    assert s.server.store.get(s.token, PATH)[0] == b"new" * 1000


# ---- write leases serialize concurrent quorum writers -----------------------

def test_lease_serializes_two_quorum_writers_zero_conflicts(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path,
                                 write_lease=WriteLeaseSpec(ttl_s=30.0))
    net = s.network
    net.partition("site", "home")
    net.partition("site2", "home")             # BOTH writers lose home
    with s.client.open(PATH, "w") as f:
        f.write(SCI_BYTES)
    assert s.client.pump() == 1                # sci holds the write lease
    assert s.replicas.lease_acquired == 1
    with bob.open(PATH, "w") as f:
        f.write(BOB_BYTES)
    assert bob.pump() == 0                     # contended: bob defers
    assert s.replicas.lease_contended == 1
    assert bob.oplog.pending()                 # queued, not lost
    net.heal("site", "home")
    net.heal("site2", "home")
    assert s.client.reconcile() == 1           # sci lands; lease released
    assert bob.pump() == 1                     # bob retries, lands ON TOP
    data, _st = s.server.store.get(s.token, PATH)
    assert data == BOB_BYTES
    # serialized, causally ordered: bob's branch covers sci's
    assert s.server.store.vts_of(PATH) == {"sci": 1, "bob": 1}
    assert s.client.conflicts == [] and bob.conflicts == []
    # no lease left dangling on any replica
    for rep in s.replicas.replicas.values():
        assert rep.store.lock_owner(PATH, net.clock) is None


def test_lease_ttl_expiry_unblocks_a_crashed_writer(tmp_path):
    fab, s, bob = two_writer_fab(tmp_path,
                                 write_lease=WriteLeaseSpec(ttl_s=10.0))
    net = s.network
    net.partition("site", "home")
    net.partition("site2", "home")
    with s.client.open(PATH, "w") as f:
        f.write(SCI_BYTES)
    assert s.client.pump() == 1                # sci parks, holds the lease
    with bob.open(PATH, "w") as f:
        f.write(BOB_BYTES)
    assert bob.pump() == 0                     # contended
    # sci never comes back; the server-side TTL is the crash fallback
    net.advance(11.0)
    assert bob.pump() == 1                     # lease lapsed: bob proceeds
    # bob built on sci's replica frontier, so his branch dominates —
    # reconcile lands bob's bytes with no conflict
    net.heal("site", "home")
    net.heal("site2", "home")
    assert bob.reconcile() == 1
    # sci's branch is dominated: its record retires quietly (counted as
    # reconciled) without touching home's bytes
    assert s.client.reconcile() == 1
    assert s.client.oplog.unreconciled() == []
    assert s.server.store.get(s.token, PATH)[0] == BOB_BYTES
    assert s.client.conflicts == [] and bob.conflicts == []
