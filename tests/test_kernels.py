"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels import ops

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D", [
    (1, 2, 2, 128, 128, 64),       # MHA square
    (2, 4, 2, 256, 256, 64),       # GQA
    (1, 8, 1, 128, 128, 128),      # MQA, MXU-width head
    (2, 2, 2, 128, 384, 64),       # cross/kv-longer (q_offset causal)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, dtype, causal):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    q_offset = Skv - Sq if causal else 0
    out = flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                          block_q=64, block_k=64, interpret=True)
    expect = ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **_tol(dtype))


@pytest.mark.parametrize("B,H,S,D,chunk", [
    (1, 2, 64, 32, 16),
    (2, 3, 128, 64, 64),
    (1, 1, 256, 64, 32),
])
def test_rwkv6_scan_sweep(B, H, S, D, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, H, S, D))))
    u = jax.random.normal(ks[4], (H, D))
    out = rwkv6_scan(r, k, v, w, u, chunk=chunk, interpret=True)
    expect = ref.rwkv6_ref(r, k, v, w, u)
    # f32 accumulation-order differences grow with S*D; scale-aware tol
    scale = float(np.max(np.abs(np.asarray(expect)))) + 1.0
    np.testing.assert_allclose(np.asarray(out) / scale,
                               np.asarray(expect) / scale,
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_strong_decay_numerics():
    """Very small decays must not overflow the chunked log-space form."""
    B, H, S, D = 1, 1, 128, 32
    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (B, H, S, D))
    k = jax.random.normal(ks[1], (B, H, S, D))
    v = jax.random.normal(ks[2], (B, H, S, D))
    w = jnp.full((B, H, S, D), 1e-6)        # near-total forgetting
    u = jax.random.normal(ks[3], (H, D))
    out = rwkv6_scan(r, k, v, w, u, chunk=32, interpret=True)
    expect = ref.rwkv6_ref(r, k, v, w, u)
    assert not np.any(np.isnan(np.asarray(out)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("B,S,di,N,chunk,block_d", [
    (1, 64, 32, 8, 16, 32),
    (2, 128, 64, 16, 64, 32),
    (1, 256, 128, 16, 32, 64),
])
def test_mamba_scan_sweep(B, S, di, N, chunk, block_d):
    ks = jax.random.split(KEY, 5)
    A = -jnp.exp(jax.random.normal(ks[0], (di, N)))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)))
    b = jax.random.normal(ks[2], (B, S, N))
    c = jax.random.normal(ks[3], (B, S, N))
    x = jax.random.normal(ks[4], (B, S, di))
    out = mamba_scan(A, dt, b, c, x, chunk=chunk, block_d=block_d,
                     interpret=True)
    expect = ref.mamba_ref(A, dt, b, c, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("sizes", [
    [128, 128, 128, 128],
    [100, 0, 300, 112],
    [0, 0, 512, 0],
    [1, 2, 3, 506],
])
def test_gmm_sweep(sizes):
    M, K, N, G = sum(sizes), 64, 128, len(sizes)
    ks = jax.random.split(KEY, 2)
    lhs = jax.random.normal(ks[0], (M, K), jnp.float32)
    rhs = jax.random.normal(ks[1], (G, K, N), jnp.float32)
    out = ops.gmm_sorted(lhs, rhs, np.asarray(sizes), block_m=128)
    expect = ref.gmm_ref(lhs, rhs, jnp.asarray(sizes))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_flash_matches_model_xla_path():
    """The model's chunked-XLA attention and the Pallas kernel agree."""
    from repro.configs import get_tiny_config
    from repro.models import init_params, forward
    from repro.data.batches import make_batch
    cfg = get_tiny_config("qwen3-8b").replace(head_dim=32)
    p = init_params(cfg, KEY)
    batch = make_batch(cfg, 2, 128)
    lo_x, _ = forward(cfg.replace(attention_impl="xla"), p, batch)
    lo_k, _ = forward(cfg.replace(attention_impl="pallas"), p, batch)
    np.testing.assert_allclose(np.asarray(lo_x, np.float32),
                               np.asarray(lo_k, np.float32),
                               rtol=5e-2, atol=5e-2)
