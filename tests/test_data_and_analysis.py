"""Data pipeline determinism/resume + HLO analyzer unit tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Fabric, FabricSpec
from repro.configs import get_tiny_config
from repro.data.pipeline import SyntheticCorpus, DataPipeline
from repro.launch.hlo_analysis import analyze, parse_module
from repro.launch.roofline import (
    collective_bytes, roofline_terms, model_flops,
)


@pytest.fixture()
def session(tmp_path):
    return Fabric(FabricSpec.star(str(tmp_path / "h"),
                                  str(tmp_path / "s"))).login("sci")


def _pipe(s, cfg, **kw):
    return DataPipeline(s.client, "home/data", cfg, batch=2, seq=16,
                        n_shards=2, **kw)


def test_pipeline_deterministic_and_resumable(session):
    s = session
    cfg = get_tiny_config("qwen3-4b")
    SyntheticCorpus(s.client, "home/data", seed=0, vocab=cfg.vocab_size,
                    shard_tokens=512).materialize(2)
    p1 = _pipe(s, cfg)
    batches1 = [p1.next_batch() for _ in range(4)]
    state = p1.state()
    nxt = p1.next_batch()
    # a fresh pipeline restored from state produces the same next batch
    p2 = _pipe(s, cfg)
    p2.restore(state)
    nxt2 = p2.next_batch()
    np.testing.assert_array_equal(np.asarray(nxt["tokens"]),
                                  np.asarray(nxt2["tokens"]))
    # and a replay from scratch matches batch-for-batch
    p3 = _pipe(s, cfg)
    for b in batches1:
        b3 = p3.next_batch()
        np.testing.assert_array_equal(np.asarray(b["tokens"]),
                                      np.asarray(b3["tokens"]))


def test_pipeline_targets_are_shifted_tokens(session):
    s = session
    cfg = get_tiny_config("qwen3-4b")
    SyntheticCorpus(s.client, "home/data", seed=0, vocab=cfg.vocab_size,
                    shard_tokens=512).materialize(2)
    p = _pipe(s, cfg)
    b = p.next_batch()
    toks = np.asarray(b["tokens"]).reshape(-1)
    tgts = np.asarray(b["targets"]).reshape(-1)
    assert np.array_equal(toks[1:], tgts[:-1])


def test_pipeline_reads_through_cache(session):
    s = session
    cfg = get_tiny_config("qwen3-4b")
    SyntheticCorpus(s.client, "home/data", seed=0, vocab=cfg.vocab_size,
                    shard_tokens=512).materialize(2)
    p = _pipe(s, cfg)
    p.next_batch()
    clock0 = s.client.network.clock
    for _ in range(6):
        p.next_batch()    # all shards cached: zero WAN time
    assert s.client.network.clock == clock0


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

TOY = """
HloModule toy

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], /*index=1*/f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.0 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.0), replica_groups={}
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ni, %ar)
}

%cond (p: (s32[], f32[8,16])) -> pred[] {
  %p = (s32[], /*index=1*/f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

ENTRY %main (a: f32[8,16]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,16]{1,0}) tuple(%z, %a)
  %w = (s32[], f32[8,16]{1,0}) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%w), index=1
}
"""


def test_analyzer_multiplies_loop_bodies():
    res = analyze(TOY)
    assert res["flops"] == 5 * 2 * 8 * 16 * 16
    assert res["coll_all-reduce"] == 5 * 8 * 16 * 4
    assert res["collective_count"] == 5


def test_analyzer_parses_tuple_types_with_index_comments():
    comps, entry = parse_module(TOY)
    assert entry == "%main"
    ops = {i.opcode for i in comps["%body"]}
    assert "while" in {i.opcode for i in comps[entry]}
    assert "dot" in ops and "all-reduce" in ops


def test_collective_bytes_flat_parser():
    txt = "  %ar = bf16[4,8] all-reduce(%x), replica_groups={}"
    out = collective_bytes(txt)
    assert out["all-reduce"] == 4 * 8 * 2


def test_roofline_dominant_term():
    t = roofline_terms(197e12, 819e9 * 2, 0.0)   # 1s compute, 2s memory
    assert t["dominant"] == "memory"
    assert abs(t["compute_s"] - 1.0) < 1e-9
    assert t["roofline_fraction_compute"] == pytest.approx(0.5)


def test_model_flops_train_vs_serve():
    assert model_flops(10, 7, train=True) == 6 * 10 * 7
    assert model_flops(10, 7, train=False) == 2 * 10 * 7
