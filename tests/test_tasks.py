"""Maintenance plane: scheduler, retry/backoff ladder, dead-letter
lifecycle, per-path lock table, and the fabric wiring.

Unit tests drive a bare :class:`MaintenanceScheduler` on a raw
:class:`Network`; integration tests go through ``FabricSpec.star(...)``
with a :class:`MaintenanceSpec` attached and assert the four registered
task families behave (convergence via scheduled resync, lease
dead-letter under partition + revive after heal, never-double-repair
across two sessions sharing one replica set, and the zero-cost
guarantee: a scheduler that never ticks leaves the trace bit-identical).
"""
import dataclasses

import pytest

from repro.core import (
    Fabric, FabricSpec, FaultPlan, LinkModel, LockTable, MB,
    MaintenanceSpec, MountSpec, Network, PartitionEvent, ReplicaPolicy,
    RetryPolicy, SiteSpec,
)
from repro.core.tasks import MaintenanceScheduler

HOME_LATENCY = 0.060


def sched_on(net=None, **spec_kw):
    net = net or Network()
    return net, MaintenanceScheduler(net, MaintenanceSpec(**spec_kw))


def mfab(tmp_path, tag="m", replica_latencies=None, maintenance=None,
         extra_sites=()):
    spec = FabricSpec.star(str(tmp_path / f"home-{tag}"),
                           str(tmp_path / f"site-{tag}"),
                           replica_latencies=replica_latencies,
                           link=LinkModel(latency_s=HOME_LATENCY),
                           extra_sites=extra_sites)
    return Fabric(dataclasses.replace(
        spec, maintenance=maintenance or MaintenanceSpec()))


# ---- RetryPolicy ------------------------------------------------------------

def test_backoff_ladder_is_deterministic_and_capped():
    p = RetryPolicy(max_retries=6, base_delay_s=1.0, multiplier=2.0,
                    max_delay_s=5.0)
    assert [p.delay_s(k) for k in range(1, 6)] == [1.0, 2.0, 4.0, 5.0, 5.0]


@pytest.mark.parametrize("kw", [
    dict(max_retries=-1),
    dict(base_delay_s=0.0),
    dict(multiplier=0.5),
    dict(max_delay_s=0.5),          # < base_delay_s
])
def test_retry_policy_validation(kw):
    with pytest.raises(ValueError):
        RetryPolicy(**kw)


def test_maintenance_spec_validation():
    with pytest.raises(ValueError):
        MaintenanceSpec(repair_period_s=0.0)
    with pytest.raises(ValueError):
        MaintenanceSpec(lock_lease_s=-1.0)


# ---- registration / periodic execution --------------------------------------

def test_periodic_task_runs_once_per_period():
    net, sched = sched_on()
    runs = []
    sched.register("beat", lambda: runs.append(net.clock), period_s=10.0)
    assert sched.run_until(35.0) == 35.0          # advance_to_stop
    assert runs == [10.0, 20.0, 30.0]
    assert sched.tasks["beat"].next_due == 40.0
    assert sched.report().tasks_run == 3


def test_duplicate_and_invalid_registration():
    _, sched = sched_on()
    sched.register("x", lambda: None, period_s=1.0)
    with pytest.raises(ValueError):
        sched.register("x", lambda: None, period_s=1.0)
    with pytest.raises(ValueError):
        sched.register("y", lambda: None, period_s=0.0)


def test_tasks_due_together_run_in_registration_order():
    net, sched = sched_on()
    order = []
    sched.register("second-name", lambda: order.append("b"), period_s=5.0)
    sched.register("a-first-alphabetically", lambda: order.append("a"),
                   period_s=5.0)
    sched.run_until(5.0)
    assert order == ["b", "a"]


def test_first_due_pins_the_initial_run():
    net, sched = sched_on()
    runs = []
    sched.register("late", lambda: runs.append(net.clock), period_s=10.0,
                   first_due=3.0)
    sched.run_until(14.0)
    assert runs == [3.0, 13.0]


def test_tick_at_fixed_clock_is_idempotent_when_nothing_due():
    net, sched = sched_on()
    sched.register("t", lambda: None, period_s=10.0)
    assert sched.tick() == 0
    net.advance(10.0)
    assert sched.tick() == 1
    assert sched.tick() == 0          # already ran; next due is 20.0


# ---- retry ladder -> dead letter -> revive ----------------------------------

def test_failing_task_dead_letters_with_backoff_history():
    net, sched = sched_on()

    def boom():
        raise RuntimeError("disk on fire")

    sched.register("bad", boom, period_s=10.0, owner="sci@site")
    sched.run_until(100.0)
    # due at 10 fails, retries at 11/13/17 fail -> dead-lettered at 17
    r = sched.report()
    assert (r.tasks_run, r.retries, r.dead_lettered) == (4, 3, 1)
    assert r.tasks["bad"]["dead"] is True
    dl = r.dead_letters[0]
    assert dl.task == "bad" and dl.owner == "sci@site"
    assert dl.attempts == 4                      # initial + 3 retries
    assert dl.backoff_s == (1.0, 2.0, 4.0)       # the ladder, verbatim
    assert dl.first_failed_at == 10.0 and dl.dead_at == 17.0
    assert len(dl.errors) == 4
    assert all("disk on fire" in e for e in dl.errors)
    assert sched.next_event() is None            # removed from the schedule


def test_success_closes_the_failure_episode():
    net, sched = sched_on()
    fails = {"n": 2}

    def flaky():
        if fails["n"]:
            fails["n"] -= 1
            raise TimeoutError("transient")
        return "ok"

    sched.register("flaky", flaky, period_s=10.0)
    sched.run_until(13.0)               # 10 fail, 11 fail, 13 success
    t = sched.tasks["flaky"]
    assert t.attempt == 0 and t.backoff_s == [] and t.errors == []
    assert t.first_failed_at is None and not t.dead
    assert t.last_result == "ok"
    assert t.next_due == 23.0           # back on the periodic cadence
    assert sched.report().retries == 2 and sched.report().dead_lettered == 0


def test_revive_restores_a_dead_task_with_a_clean_episode():
    net, sched = sched_on()
    broken = {"yes": True}

    def sometimes():
        if broken["yes"]:
            raise ConnectionError("wan down")
        return 1

    sched.register("resync", sometimes, period_s=10.0)
    sched.run_until(30.0)
    assert sched.tasks["resync"].dead
    broken["yes"] = False               # the heal
    t = sched.revive("resync", delay_s=2.0)
    assert not t.dead and t.attempt == 0 and t.next_due == 32.0
    sched.run_until(32.0)
    r = sched.report()
    assert r.tasks["resync"]["dead"] is False
    assert sched.tasks["resync"].last_result == 1
    assert len(r.dead_letters) == 1     # the record is history, kept


def test_revive_on_a_live_task_is_a_no_op():
    net, sched = sched_on()
    sched.register("fine", lambda: None, period_s=10.0)
    before = sched.tasks["fine"].next_due
    assert sched.revive("fine").next_due == before


# ---- lock table -------------------------------------------------------------

def test_lock_conflicts_are_counted_not_blocked():
    lt = LockTable(lease_s=30.0)
    assert lt.acquire("rs0/a", "sci@site", now=0.0)
    assert not lt.acquire("rs0/a", "bob@site2", now=5.0)
    assert lt.conflicts == 1
    assert lt.holder("rs0/a", 5.0) == "sci@site"


def test_same_owner_reacquire_extends_the_lease():
    lt = LockTable(lease_s=30.0)
    assert lt.acquire("k", "sci", now=0.0)
    assert lt.acquire("k", "sci", now=25.0)       # extend, not conflict
    assert lt.conflicts == 0
    assert lt.holder("k", 50.0) == "sci"          # alive: 25 + 30 > 50
    assert lt.holder("k", 55.0) is None


def test_expired_lock_is_free_and_release_is_owner_checked():
    lt = LockTable(lease_s=10.0)
    lt.acquire("k", "sci", now=0.0)
    assert lt.acquire("k", "bob", now=11.0)       # expired: no conflict
    assert lt.conflicts == 0
    lt.release("k", "sci")                        # not the holder: no-op
    assert lt.holder("k", 12.0) == "bob"
    lt.release("k", "bob")
    assert lt.holder("k", 12.0) is None
    with pytest.raises(ValueError):
        LockTable(lease_s=0.0)


# ---- fabric integration -----------------------------------------------------

def test_scheduled_resync_converges_a_replica(tmp_path):
    fab = mfab(tmp_path, replica_latencies={"r1": 0.005})
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    assert s.scheduler is fab.scheduler           # one plane per fabric
    payload = b"A" * (1 * MB)
    s.server.store.put(s.token, "home/d/x.bin", payload)
    t0 = s.network.clock
    s.scheduler.run_until(t0 + 31.0)              # past resync_period_s
    s.scheduler.quiesce()
    with s.client.open("home/d/x.bin") as f:
        assert f.read() == payload
    assert s.client.cache.fills_from == {"r1": 1}  # replica, not home
    r = s.maintenance_report()
    assert set(r.tasks) == {"lease:sci@site", "reconcile:sci@site",
                            "resync:sci@site", "repair:sci@site"}
    assert r.tasks_run > 0 and r.dead_lettered == 0


def test_lease_task_dead_letters_under_partition_and_revives(tmp_path):
    fab = mfab(tmp_path)
    s = fab.login("sci")
    assert s.client.lock("home/d/f")
    net = s.network
    t0 = net.clock
    # declarative chaos: a 40 s site<->home outage opening now — the
    # scheduler pumps the plan as it walks the clock, and the window
    # auto-heals at t0+40 (no hand-rolled partition/heal choreography)
    fab.arm_faults(FaultPlan(events=(
        PartitionEvent(at_s=t0, a="site", b="home", duration_s=40.0),)))
    s.scheduler.run_until(t0 + 40.0)
    # lease renewal fails at t0+10, retries at +11/+13/+17, then dies
    r = s.maintenance_report()
    assert r.dead_lettered == 1
    dl = r.dead_letters[0]
    assert dl.task == "lease:sci@site"
    assert dl.attempts == 4 and dl.backoff_s == (1.0, 2.0, 4.0)
    lm = s.client.leases["home/"]
    assert lm.at_risk == {"home/d/f"}      # honest: unconfirmed, not held
    assert not net.is_partitioned("site", "home")   # window lapsed
    s.scheduler.revive("lease:sci@site")
    s.scheduler.run_until(net.clock + 11.0)
    r = s.maintenance_report()
    assert r.tasks["lease:sci@site"]["dead"] is False
    assert r.dead_lettered == 1            # history, not a live failure
    assert lm.at_risk == set() and lm.held == {"home/d/f"}
    assert s.server.store.lock_owner("home/d/f", net.clock) == "sci"


def test_two_sessions_never_double_repair_one_path(tmp_path):
    """login + attach share one ReplicaSet; both repair tasks see the
    same lagging path while the first repair's ack is still in flight —
    the per-path lock turns the race into a counted conflict, never a
    second repair."""
    fab = mfab(tmp_path, replica_latencies={"r1": 0.005},
               extra_sites=(SiteSpec("site2",
                                     root=str(tmp_path / "site2")),))
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    fab.attach(s, "site2", owner="bob", mounts=[MountSpec("home/")])
    path = "home/out/hot.bin"
    payload = b"A" * (1 * MB)
    net = s.network
    with s.client.open(path, "w") as f:
        f.write(payload)
    net.partition("home", "r1")
    assert s.client.pump() == 1     # home acked; replica fan-out deferred
    net.heal("home", "r1")
    rep = s.replicas.replicas["r1"]
    assert path in rep.lagging
    sched = s.scheduler
    now = net.clock
    for name in ("repair:sci@site", "repair:bob@site2"):
        assert name in sched.tasks                 # attach registered too
        sched.tasks[name].next_due = now + 1.0     # the race, made exact
    sched.run_until(now + 1.0)
    r = fab.maintenance_report()
    assert r.repairs == 1                # exactly one launch...
    assert r.lock_conflicts >= 1         # ...the loser skipped, counted
    assert r.double_repairs == 0         # and never a second repair
    sched.quiesce()
    assert path not in rep.lagging
    assert rep.store.get(rep.token, path)[0] == payload


def test_unticked_scheduler_leaves_the_trace_bit_identical(tmp_path):
    """MaintenanceSpec set but never ticked ⇒ every wire event identical
    to a fabric with no maintenance plane at all (the zero-cost gate)."""
    def drive(fab):
        s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
        payload = b"C" * (2 * MB)
        with s.client.open("home/d/y.bin", "w") as f:
            f.write(payload)
        s.client.pump()
        with s.client.open("home/d/y.bin") as f:
            assert f.read() == payload
        return s.network.trace

    plain_spec = FabricSpec.star(str(tmp_path / "home-p"),
                                 str(tmp_path / "site-p"),
                                 replica_latencies={"r1": 0.005},
                                 link=LinkModel(latency_s=HOME_LATENCY))
    plain = drive(Fabric(plain_spec))
    scheduled = drive(mfab(tmp_path, tag="q",
                           replica_latencies={"r1": 0.005}))
    assert plain == scheduled
