"""Per-endpoint NIC budget properties (the congestion-model invariants).

Three property families (via the ``_propcheck`` hypothesis shim):

  * conservation — with a budget ``B`` on every endpoint, the bytes
    attributed to any endpoint never exceed ``B x elapsed`` once the
    batch drains (the NIC serializer cannot be oversubscribed);
  * no-budget equivalence — with budgets unset the reservation math is
    bit-for-bit the pure link formula (the PR 3 trace), and an
    effectively-infinite budget reproduces the unbudgeted trace exactly;
  * determinism — same ops => identical trace and final clock still
    holds with oversubscribed budgets in play.

Plus directed checks: oversubscription stretches completion to the NIC
backlog, and ``estimated_completion`` agrees with the reservation it
predicts.
"""
import random

from _propcheck import given, settings, strategies as st

from repro.core.striping import StripedTransfer
from repro.core.transport import Endpoint, LinkModel, MB, Network

N_EPS = 4


def _mknet(latency: float = 0.010, budget=None) -> Network:
    net = Network(link=LinkModel(latency_s=latency))
    for i in range(N_EPS):
        Endpoint(f"e{i}", net)
        if budget is not None:
            net.set_nic_budget(f"e{i}", budget)
    return net


def _run_ops(net, ops):
    issued = []
    for si, di, nbytes, wait_now in ops:
        src, dst = f"e{si % N_EPS}", f"e{di % N_EPS}"
        if src == dst:
            continue
        t = net.transfer(src, dst, "op", nbytes)
        issued.append(t)
        if wait_now:
            net.wait(t)
    net.wait_all(issued)
    return issued


OPS = st.lists(
    st.tuples(st.integers(min_value=0, max_value=N_EPS - 1),
              st.integers(min_value=0, max_value=N_EPS - 1),
              st.integers(min_value=0, max_value=4 * 1024 * 1024),
              st.booleans()),
    min_size=1, max_size=48)


@given(OPS)
@settings(max_examples=50, deadline=None)
def test_endpoint_bytes_never_exceed_budget_times_elapsed(ops):
    """Conservation: an endpoint with budget B moves at most B x elapsed
    bytes — the serializer stretches completions instead of letting a
    fan-out exceed the shared uplink."""
    budget = 20 * MB
    net = _mknet(budget=budget)
    _run_ops(net, ops)
    elapsed = net.drain()
    for ep, nbytes in net.per_endpoint_bytes.items():
        assert nbytes <= budget * elapsed * (1 + 1e-9) + 1e-6, \
            (ep, nbytes, budget * elapsed)


@given(OPS)
@settings(max_examples=50, deadline=None)
def test_no_budget_reservation_is_pure_link_formula(ops):
    """Budgets of None reproduce the PR 3 reservation math bit-for-bit:
    every trace row's duration equals ``link.stream_time(nbytes)``."""
    net = _mknet()
    _run_ops(net, ops)
    for src, dst, _m, nbytes, _ch, start, completion in net.trace:
        want = net.link_between(src, dst).stream_time(nbytes)
        assert abs((completion - start) - want) < 1e-9


@given(OPS)
@settings(max_examples=25, deadline=None)
def test_infinite_budget_trace_identical_to_unbudgeted(ops):
    """A budget too large to bind must not perturb a single reservation:
    the trace and final clock match the unbudgeted run exactly."""
    plain = _mknet()
    _run_ops(plain, ops)
    capped = _mknet(budget=float("inf"))
    _run_ops(capped, ops)
    assert plain.trace == capped.trace
    assert plain.clock == capped.clock


@given(st.integers(min_value=0, max_value=2 ** 31))
@settings(max_examples=10, deadline=None)
def test_same_ops_identical_trace_under_oversubscription(seed):
    """Determinism survives the NIC model: same ops => identical trace
    and clock even with every endpoint's budget binding."""

    def one_run():
        rng = random.Random(seed)
        net = _mknet(budget=10 * MB)
        ops = [(rng.randrange(N_EPS), rng.randrange(N_EPS),
                rng.randrange(2 * 1024 * 1024), rng.random() < 0.5)
               for _ in range(32)]
        _run_ops(net, ops)
        return net.trace, net.clock

    trace1, clock1 = one_run()
    trace2, clock2 = one_run()
    assert trace1 == trace2
    assert clock1 == clock2


def test_oversubscription_stretches_completion_to_nic_backlog():
    """Two concurrent transfers from one endpoint to DIFFERENT pairs:
    each fits its link alone, but the shared NIC serializes them — the
    second completes a full nbytes/budget after the first's service."""
    budget = 10 * MB
    net = _mknet(budget=budget)
    n = 4 * MB
    t1 = net.transfer("e0", "e1", "a", n)
    t2 = net.transfer("e0", "e2", "b", n)
    assert abs(t1.completion - (n / budget)) < 1e-9        # NIC-bound
    assert abs(t2.completion - 2 * (n / budget)) < 1e-9    # queued behind
    net.drain()
    assert net.per_endpoint_bytes["e0"] <= budget * net.clock * (1 + 1e-9)


def test_striped_payload_charges_shared_nic_once():
    """Striping 12-wide must not multiply NIC capacity: the striped
    group completes no earlier than total_bytes / budget."""
    budget = 25 * MB
    net = _mknet(latency=0.030, budget=budget)
    xfer = StripedTransfer(net)
    payload = b"s" * (48 * MB)
    group = xfer.begin("e0", "e1", payload)
    assert group.completion >= len(payload) / budget - 1e-9
    net.drain()
    assert net.per_endpoint_bytes["e0"] <= budget * net.clock * (1 + 1e-9)


def test_estimated_completion_matches_actual_reservation():
    """The routing estimator prices a candidate with exactly the
    completion the reservation would get (single stream, unpartitioned),
    including channel queueing and NIC backlog."""
    net = _mknet(budget=10 * MB)
    # preload queue + NIC backlog deterministically
    for _ in range(3):
        net.transfer("e0", "e1", "bg", 2 * MB)
    for nbytes in (0, 1000, 1 * MB, 8 * MB):
        est = net.estimated_completion("e0", "e1", nbytes)
        got = net.transfer("e0", "e1", "probe", nbytes)
        assert abs(est - got.completion) < 1e-9, (nbytes, est, got)
    net.drain()


def test_estimated_completion_is_read_only_and_inf_when_partitioned():
    net = _mknet(budget=10 * MB)
    before = (dict(net._nic_free), net.clock, len(net.trace))
    net.estimated_completion("e0", "e1", 1 * MB)
    assert (dict(net._nic_free), net.clock, len(net.trace)) == before
    net.partition("e0", "e1")
    assert net.estimated_completion("e0", "e1", 1 * MB) == float("inf")


def test_removing_budget_drops_backlog():
    """Lifting a cap drains the serializer: a budget re-applied later
    must not inherit phantom queueing from before the uncapped interval."""
    net = _mknet(budget=10 * MB)
    net.transfer("e0", "e1", "bg", 200 * MB)      # 20 s of backlog
    net.set_nic_budget("e0", None)
    net.set_nic_budget("e1", None)
    net.drain()
    net.set_nic_budget("e0", 10 * MB)
    t = net.transfer("e0", "e2", "probe", 1 * MB)
    assert t.completion <= net.clock + 1 * MB / (10 * MB) + 1e-9
