import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # import _propcheck anywhere


def pytest_addoption(parser):
    parser.addoption(
        "--seed", action="store", type=int, default=None,
        help="Seed for the _propcheck property-test shim (reproduces "
             "generated cases; ignored when real hypothesis is installed).")


def pytest_configure(config):
    seed = config.getoption("--seed")
    if seed is not None:
        import _propcheck
        _propcheck.GLOBAL_SEED = seed
