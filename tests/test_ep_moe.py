"""shard_map expert-parallel MoE vs the GSPMD scatter path (8 host devs)."""
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_ep_moe_matches_gspmd_path():
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, {SRC!r})
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import get_tiny_config
        from repro.launch.mesh import make_test_mesh
        from repro.models.moe import moe_init, moe_apply
        from repro.parallel.ep_moe import ep_moe_apply

        cfg = get_tiny_config('qwen3-moe-30b-a3b')
        # drop-free capacity so both dispatch strategies agree exactly
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=32.0, num_experts=8,
            experts_per_token=2, chunk_tokens=0))
        key = jax.random.PRNGKey(0)
        p = moe_init(cfg, key)
        B, S, d = 8, 16, cfg.d_model
        x = (jax.random.normal(jax.random.fold_in(key, 1), (B, S, d))
             .astype(cfg.dtype))
        ref, _ = moe_apply(cfg, p, x)

        mesh = make_test_mesh(2, 4)   # data=2, model=4 -> 2 experts/shard
        out = ep_moe_apply(cfg, p, x, mesh, tp_axis='model',
                           batch_axes=('data',), capacity_factor=32.0)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32)))) + 1e-6
        assert err / scale < 5e-2, (err, scale)
        print('EP_MOE_OK', err / scale)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "EP_MOE_OK" in r.stdout
