"""Cold-read latency vs. replica count on the simulated WAN.

A user's home space sits behind a high-RTT link (60 ms); read replicas are
placed at nearby sites (4-16 ms).  Each row sweeps a cold cache over
``N_FILES`` objects and reports the modeled WAN seconds:

    replica_read/cold_replicas=<n>,us_per_call,<modeled seconds>

The final rows inject faults: with the nearest replica partitioned the
sweep degrades to the next source (ultimately home) instead of erroring.
Run standalone, the script exits non-zero if replicas do not strictly beat
the single-home baseline — the acceptance gate for the replica fabric.
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, star_fabric, timed

N_FILES = 8
HOME_LATENCY = 0.060
REPLICA_COUNTS = (0, 1, 2, 4)


def _build_session(n_replicas: int, root: str, tag: str, file_size: int):
    from repro.core import ReplicaPolicy

    sites = {f"r{i + 1}": 0.004 * (i + 1) for i in range(n_replicas)}
    fab = star_fabric(f"{root}/home-{tag}", f"{root}/site-{tag}",
                      latency_s=HOME_LATENCY, replica_latencies=sites)
    s = fab.login("bench",
                  replicas=ReplicaPolicy(sites=tuple(sites))
                  if sites else None)
    for i in range(N_FILES):
        s.server.store.put(s.token, f"home/data/f{i}.bin", b"x" * file_size)
    if s.replicas is not None:
        s.replicas.resync()
    return s


def _cold_sweep(s, file_size: int) -> float:
    t0 = s.client.network.clock
    for i in range(N_FILES):
        with s.client.open(f"home/data/f{i}.bin") as f:
            assert len(f.read()) == file_size
    return s.client.network.clock - t0


def run(smoke: bool = False) -> int:
    from repro.core import MB

    file_size = 1 * MB if smoke else 4 * MB
    counts = (0, 1, 2) if smoke else REPLICA_COUNTS
    root = tempfile.mkdtemp(prefix="fig_replica_read_")
    failures = []
    try:
        modeled = {}
        for n in counts:
            s = _build_session(n, root, f"n{n}", file_size)
            us, dt = timed(lambda s=s: _cold_sweep(s, file_size))
            modeled[n] = dt
            emit(f"replica_read/cold_replicas={n}_s", us, f"{dt:.4f}")
        for n in counts[1:]:
            if not modeled[n] < modeled[0]:
                failures.append(
                    f"{n} replicas ({modeled[n]:.4f}s) not faster than "
                    f"single-home baseline ({modeled[0]:.4f}s)")

        # route memoization: a second cold sweep over the same paths hits
        # the per-(client, path) candidate cache (the catalog is quiet),
        # instead of rebuilding the ranked list per read
        s = _build_session(2, root, "memo", file_size)
        _cold_sweep(s, file_size)                    # populate: all misses
        for i in range(N_FILES):
            s.client.cache.evict(f"home/data/f{i}.bin")
        us, _dt = timed(lambda: _cold_sweep(s, file_size))
        hits, misses = s.replicas.route_hits, s.replicas.route_misses
        rate = hits / max(hits + misses, 1)
        emit("replica_read/route_cache_hit_rate", us, f"{rate:.2f}")
        if hits < N_FILES:
            failures.append(
                f"route cache: only {hits} hits over {hits + misses} "
                f"routes (want >= {N_FILES} on the re-sweep)")

        # fault: nearest replica partitioned -> degrade to the 2nd replica
        s = _build_session(2, root, "part2", file_size)
        s.client.network.partition("site", "r1")
        us, dt = timed(lambda: _cold_sweep(s, file_size))
        emit("replica_read/cold_2replicas_nearest_partitioned_s", us,
             f"{dt:.4f}")
        if s.client.cache.fills_from.get("r2") != N_FILES:
            failures.append("partitioned r1 did not fall back to r2")

        # fault: only replica partitioned -> degrade all the way to home
        s = _build_session(1, root, "part1", file_size)
        s.client.network.partition("site", "r1")
        us, dt = timed(lambda: _cold_sweep(s, file_size))
        emit("replica_read/cold_1replica_partitioned_home_fallback_s", us,
             f"{dt:.4f}")
        if s.client.cache.fills_from.get("home") != N_FILES:
            failures.append("partitioned replica did not fall back to home")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)   # keep stdout valid CSV
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("replica_read: OK (replicas beat home; partitions degrade, "
              "never error)")
    raise SystemExit(rc)
