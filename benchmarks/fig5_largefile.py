"""Paper Fig 5 + Table 2: 1 GB file access over the WAN.

``wc -l`` on a 1 GB file: XUFS pays one striped fetch on first open then
goes local; the GPFS-WAN analogue re-reads over the WAN every run.
Table 2 compares the striped fetch (XUFS), a GridFTP-like striped copy
(TGCP) and an encrypted single-stream copy (SCP).
"""
from __future__ import annotations

import tempfile

from benchmarks.common import emit, star_fabric, timed

GB = 1024 * 1024 * 1024
SIZE = 1 * GB
SMOKE_SIZE = 8 * 1024 * 1024      # striped path still exercised


def run(smoke: bool = False) -> None:
    size = SMOKE_SIZE if smoke else SIZE
    with tempfile.TemporaryDirectory() as td:
        fab = star_fabric(td + "/h", td + "/s")
        net = fab.network
        s = fab.login("bench")
        payload = b"line\n" * (size // 5)
        s.server.store.put(s.token, "home/data/big.dat", payload)

        # ---- fig5: five consecutive "wc -l" runs in XUFS -----------------
        for run_i in range(1, 3 if smoke else 6):
            def wc_run():
                c0 = net.clock
                with s.client.open("home/data/big.dat") as f:
                    data = f.read()
                n = data.count(b"\n")
                assert n == size // 5
                return net.clock - c0

            us, wan_s = timed(wc_run)
            emit(f"fig5/xufs_wc_run{run_i}_s", us, round(wan_s, 2))

        # ---- fig5: GPFS-WAN analogue (remote block reads every run) ------
        for run_i in range(1, 3):
            def remote_run():
                c0 = net.clock
                # GPFS-WAN streams blocks over a handful of connections
                s.client.transfer.send("home", "site", payload,
                                       max_stripes=4)
                return net.clock - c0

            us, wan_s = timed(remote_run)
            emit(f"fig5/gpfswan_wc_run{run_i}_s", us, round(wan_s, 2))

        # ---- table2: copy-command comparison ------------------------------
        def tgcp():
            c0 = net.clock
            s.client.transfer.send("home", "site", payload)   # 12 streams
            return net.clock - c0

        us, wan_s = timed(tgcp)
        emit("table2/tgcp_copy_s", us, round(wan_s, 2))

        def scp():
            c0 = net.clock
            s.client.transfer.send("home", "site", payload, max_stripes=1,
                                   encrypted=True)
            return net.clock - c0

        us, wan_s = timed(scp)
        emit("table2/scp_copy_s", us, round(wan_s, 2))
