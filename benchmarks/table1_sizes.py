"""Paper Table 1: cumulative file-size distribution of the active store.

The adaptation censuses the tensor objects a checkpoint of each assigned
architecture puts in the home store (the analogue of TACC's scratch
space), and reports the cumulative-bytes distribution plus the fraction of
bytes that ride the striped path (>64 KB) — the paper's observation that
9% of files hold 98.5% of bytes is what justifies striping + whole-file
caching.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed


def leaf_sizes_for_arch(arch: str):
    import jax
    from repro.configs import get_config
    from repro.models import init_params

    cfg = get_config(arch)
    spec = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return [int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(spec)]


BUCKETS = [(500 << 20, ">500M"), (400 << 20, ">400M"), (300 << 20, ">300M"),
           (200 << 20, ">200M"), (100 << 20, ">100M"), (1 << 20, ">1M"),
           (512 << 10, ">0.5M"), (256 << 10, ">0.25M")]


def run(smoke: bool = False) -> None:
    from repro.configs import ARCH_IDS
    from repro.core.striping import STRIPE_THRESHOLD

    archs = ARCH_IDS[:2] if smoke else ARCH_IDS   # smoke: 2-arch census
    all_sizes = []

    def census():
        for arch in archs:
            all_sizes.extend(leaf_sizes_for_arch(arch))
        return len(all_sizes)

    us, nfiles = timed(census)
    sizes = np.asarray(all_sizes, np.float64)
    total = sizes.sum()
    emit("table1/census_objects", us, int(nfiles))
    for threshold, label in BUCKETS:
        frac_files = float((sizes > threshold).mean())
        frac_bytes = float(sizes[sizes > threshold].sum() / total)
        emit(f"table1/bytes_frac_{label}", 0.0, round(frac_bytes, 4))
        emit(f"table1/files_frac_{label}", 0.0, round(frac_files, 4))
    striped = float(sizes[sizes > STRIPE_THRESHOLD].sum() / total)
    emit("table1/bytes_on_striped_path", 0.0, round(striped, 6))
