"""Quorum write-ack: latency vs. availability under a home outage.

A client site writes behind a 60 ms home link with two replica sites
(5/15 ms).  Three write-ack policies are swept over the same workload:

  W=1        the legacy policy — the home apply alone acks, replica
             fan-out is best-effort;
  majority   W = N//2+1 of home+replicas;
  all        W = N.

Rows report modeled WAN seconds / fractions:

  quorum_write/ack_latency_<policy>_s          healthy-network mean time
                                               from apply start to W-th ack
  quorum_write/drain_<policy>_s                healthy-network virtual time
                                               for the full sync() drain of
                                               the op set (clock stops at
                                               each op's W-th ack; later
                                               acks settle in background)
  quorum_write/home_outage_<policy>_acked_frac fraction of writes that
                                               became client-complete with
                                               home fully partitioned
  quorum_write/outage_majority_fresh_read_frac cold reads served fresh
                                               from acked replicas during
                                               the outage
  quorum_write/post_heal_<policy>_home_converged_frac
                                               writes that reached home
                                               after the heal

Run standalone (and from ``run.py --smoke`` in CI), the script exits
non-zero unless: ack latency strictly orders W=1 < majority < all; under
overlapped fan-out the DRAIN time also orders W=1 <= majority and
majority strictly beats all (the channel-clock acceptance gate — on the
old inline clock every policy paid the same full fan-out drain);
majority keeps acking (and reads stay fresh) through the outage while
W=1 and W=all stall; and every policy converges home after the heal.
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, star_fabric, timed

HOME_LATENCY = 0.060
REPLICA_SITES = {"r1": 0.005, "r2": 0.015}
HOME_PAIRS = (("site", "home"), ("home", "r1"), ("home", "r2"))
POLICIES = (("w1", 1), ("majority", "majority"), ("all", "all"))


def _login(policy, root: str, tag: str):
    from repro.core import ReplicaPolicy

    fab = star_fabric(f"{root}/home-{tag}", f"{root}/site-{tag}",
                      latency_s=HOME_LATENCY,
                      replica_latencies=REPLICA_SITES)
    return fab.login("bench",
                     replicas=ReplicaPolicy(sites=tuple(REPLICA_SITES),
                                            write_quorum=policy))


def _write_files(s, n_files: int, size: int, prefix: str) -> list:
    paths = []
    for i in range(n_files):
        p = f"home/out/{prefix}{i}.dat"
        with s.client.open(p, "w") as f:
            f.write(bytes([i % 251]) * size)
        paths.append(p)
    return paths


def run(smoke: bool = False) -> int:
    from repro.core import MB

    n_files = 2 if smoke else 6
    size = 64 * 1024 if smoke else MB // 2
    root = tempfile.mkdtemp(prefix="fig_quorum_write_")
    failures = []
    try:
        # ---- healthy network: time-to-W-th-ack per policy ----------------
        ack = {}
        for name, policy in POLICIES:
            s = _login(policy, root, f"lat-{name}")
            _write_files(s, n_files, size, "lat")

            def drain(s=s):
                s.client.sync()
                lats = list(s.client.ack_wan_s.values())
                return sum(lats) / len(lats)

            us, mean_s = timed(drain)
            ack[name] = mean_s
            emit(f"quorum_write/ack_latency_{name}_s", us, f"{mean_s:.4f}")
        if not ack["w1"] < ack["majority"] < ack["all"]:
            failures.append(
                f"ack latency not ordered w1<majority<all: {ack}")

        # ---- healthy network: full drain time per policy -----------------
        # Same op set, overlapped fan-out: the flusher's clock stops at
        # each op's W-th ack, so fewer required acks => faster drain.
        drain = {}
        for name, policy in POLICIES:
            s = _login(policy, root, f"drain-{name}")
            _write_files(s, n_files, size, "drn")

            def timed_drain(s=s):
                c0 = s.client.network.clock
                s.client.sync()
                return s.client.network.clock - c0

            us, drain_s = timed(timed_drain)
            drain[name] = drain_s
            emit(f"quorum_write/drain_{name}_s", us, f"{drain_s:.4f}")
            s.client.network.drain()     # settle background fan-out
        if not (drain["w1"] <= drain["majority"] < drain["all"]):
            failures.append(
                f"drain time not ordered w1<=majority<all under "
                f"overlapped fan-out: {drain}")

        # ---- home outage: who keeps acking? ------------------------------
        healed = {}
        for name, policy in POLICIES:
            s = _login(policy, root, f"out-{name}")
            healed[name] = s
            for pair in HOME_PAIRS:
                s.client.network.partition(*pair)
            paths = _write_files(s, n_files, size, "out")

            us, acked = timed(lambda s=s: float(s.client.sync()) / n_files)
            emit(f"quorum_write/home_outage_{name}_acked_frac", us,
                 f"{acked:.2f}")
            want = 1.0 if name == "majority" else 0.0
            if acked != want:
                failures.append(
                    f"{name}: acked_frac {acked} during outage, want {want}")

            if name == "majority":
                # reads stay fresh: cold fills come from acked replicas
                fresh = 0
                for i, p in enumerate(paths):
                    s.client.cache.evict(p)
                    with s.client.open(p) as f:
                        fresh += int(f.read() == bytes([i % 251]) * size)
                us2 = 0.0
                emit("quorum_write/outage_majority_fresh_read_frac", us2,
                     f"{fresh / n_files:.2f}")
                if fresh != n_files:
                    failures.append(
                        f"majority: {fresh}/{n_files} fresh reads in outage")
                if s.client.cache.fills_from.get("home"):
                    failures.append("majority: outage reads touched home")

        # ---- heal: every policy must converge home -----------------------
        for name, _ in POLICIES:
            s = healed[name]
            for pair in HOME_PAIRS:
                s.client.network.heal(*pair)
            s.client.reconnect()         # reattach + reconcile parked ops
            s.client.sync()              # drain any stalled backlog
            ok = 0
            for i in range(n_files):
                p = f"home/out/out{i}.dat"
                try:
                    data, _st = s.server.store.get(s.token, p)
                except FileNotFoundError:
                    continue
                ok += int(data == bytes([i % 251]) * size)
            emit(f"quorum_write/post_heal_{name}_home_converged_frac", 0.0,
                 f"{ok / n_files:.2f}")
            if ok != n_files:
                failures.append(
                    f"{name}: only {ok}/{n_files} writes reached home "
                    "after heal")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)   # keep stdout valid CSV
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("quorum_write: OK (majority survives the home outage; "
              "W=1 stalls; heal converges home; overlapped fan-out "
              "drains majority strictly faster than all)")
    raise SystemExit(rc)
