"""Paper Figs 2+3 (IOzone write/read): XUFS vs the always-remote baseline.

Write path: XUFS closes locally (write-behind) vs GPFS-WAN-analogue
synchronous remote write.  Read path: first access (cold striped fetch) vs
warm cache vs always-remote.  File sizes 1 MB -> 1 GB as in the paper;
``derived`` is modeled MB/s on the virtual WAN.
"""
from __future__ import annotations

import tempfile

from benchmarks.common import emit, star_fabric, timed

MB = 1024 * 1024
SIZES = [1 * MB, 16 * MB, 64 * MB, 256 * MB, 1024 * MB]
SMOKE_SIZES = [1 * MB, 4 * MB]


def run(smoke: bool = False) -> None:
    with tempfile.TemporaryDirectory() as td:
        fab = star_fabric(td + "/h", td + "/s")
        net = fab.network
        s = fab.login("bench")
        for size in (SMOKE_SIZES if smoke else SIZES):
            label = f"{size // MB}M"
            payload = b"\x5a" * size

            # ---- XUFS write: local close + async drain ------------------
            def xufs_write():
                c0 = net.clock
                with s.client.open(f"home/io/w_{label}", "w") as f:
                    f.write(payload)
                blocked = net.clock - c0          # what the app saw: ~0
                s.client.sync()                   # drain off the critical path
                return blocked

            us, blocked = timed(xufs_write)
            emit(f"fig2/xufs_write_{label}_app_blocked_wan_s", us,
                 "local" if blocked < 1e-6 else round(blocked, 4))

            # ---- remote-synchronous write (GPFS-WAN analogue) -----------
            def remote_write():
                c0 = net.clock
                s.client.transfer.send("site", "home", payload,
                                       max_stripes=1)
                s.server.store.put(s.token, f"home/io/r_{label}", payload)
                return size / MB / (net.clock - c0)

            us, mbps = timed(remote_write)
            emit(f"fig2/remote_write_{label}_MBps", us, round(mbps, 1))

            # ---- XUFS cold read (striped whole-file fetch) ---------------
            s.server.store.put(s.token, f"home/io/rd_{label}", payload)

            def cold_read():
                c0 = net.clock
                with s.client.open(f"home/io/rd_{label}") as f:
                    f.read()
                return size / MB / (net.clock - c0)

            us, mbps = timed(cold_read)
            emit(f"fig3/xufs_read_cold_{label}_MBps", us, round(mbps, 1))

            # ---- XUFS warm read (cache hit: local parallel FS speed) -----
            def warm_read():
                c0 = net.clock
                with s.client.open(f"home/io/rd_{label}") as f:
                    f.read()
                dt = net.clock - c0
                return size / MB / dt if dt > 0 else float("inf")

            us, mbps = timed(warm_read)
            emit(f"fig3/xufs_read_warm_{label}_local", us,
                 "local" if mbps == float("inf") else round(mbps, 1))

            # ---- always-remote read (single-stream, per-open) -----------
            def remote_read():
                c0 = net.clock
                s.client.transfer.send("home", "site", payload,
                                       max_stripes=1)
                return size / MB / (net.clock - c0)

            us, mbps = timed(remote_read)
            emit(f"fig3/remote_read_{label}_MBps", us, round(mbps, 1))
