"""Paper §2.2: file-sharing census — private-by-default namespaces.

The paper found 1 of 1,964 users shared files.  XUFS's answer is private
per-user namespaces; the replica fabric must not widen that.  Two parts:

  * **Private census** (the original): N user sessions — now each with
    read replicas placed — against one network.  Verifies (a) zero
    cross-user object visibility, (b) zero cross-user auth-token
    validity *including against every replica store* (a replica of a
    private home space is as private as the home), and reports the
    census.

  * **Shared-mount census** (replica placement): many clients mount the
    SAME home space (the paper's shared project data case).  With no
    replicas every cold read hammers the far home link; with replicas
    placed, fills route to near replica sites.  Reports where the fills
    landed (`home_fills` vs `replica_fills`), the offload fraction, and
    the modeled WAN time for the sweep — how placement changes the
    sharing picture.

Run standalone, exits non-zero if privacy is violated or if replica
placement fails to serve a shared namespace faster than home-only.
"""
from __future__ import annotations

import os
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (
    REPO_ROOT, cache_fill_totals, emit, percentiles, read_bench_json,
    timed, write_bench_json,
)

N_USERS = 16                      # census check is O(n^2)
SMOKE_USERS = 4
N_CLIENTS = 8                     # shared-mount readers
SMOKE_CLIENTS = 3
N_SHARED_FILES = 12
SMOKE_SHARED_FILES = 4

# ---- scale census (batched discrete-event engine) ----------------------
SCALE_SERVERS = 8                 # census fan-in targets
SCALE_WAVES = 8                   # rounds of (estimate-all, transfer-all)
SCALE_CHANNELS = 2                # small pool => queue feedback steers
SCALE_USERS = 2000                # run.py full; run.py --smoke uses fewer
SCALE_SMOKE_USERS = 300
RATIO_USERS = 1000                # the speedup ratio is pinned at 1k
BASELINE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "census_baseline.json")
BENCH_JSON_PATH = os.path.join(REPO_ROOT, "BENCH_census.json")


def _private_census(n_users: int) -> int:
    """N private namespaces on ONE declared fabric: the whole multi-user
    topology is a single FabricSpec, and each user is one login."""
    from repro.core import (
        AuthError, Fabric, FabricSpec, LinkModel, LinkSpec, ReplicaPolicy,
        SiteSpec,
    )

    failures = 0
    with tempfile.TemporaryDirectory() as td:
        sites, links = [], []
        for i in range(n_users):
            sites += [SiteSpec(f"home{i}", root=f"{td}/h{i}"),
                      SiteSpec(f"site{i}", root=f"{td}/s{i}"),
                      SiteSpec(f"u{i}r1"), SiteSpec(f"u{i}r2")]
            links += [LinkSpec(f"site{i}", f"u{i}r1", latency_s=0.005),
                      LinkSpec(f"site{i}", f"u{i}r2", latency_s=0.015)]
        fab = Fabric(FabricSpec(sites=tuple(sites), links=tuple(links),
                                link=LinkModel(latency_s=0.060)))
        sessions = []

        def make_users():
            for i in range(n_users):
                s = fab.login(
                    f"user{i}", home=f"home{i}", site=f"site{i}",
                    replicas=ReplicaPolicy(sites=(f"u{i}r1", f"u{i}r2")))
                s.server.store.put(s.token, f"home/private_{i}.dat",
                                   b"secret" * 100)
                s.replicas.resync()          # private bytes now replicated
                sessions.append(s)
            return len(sessions)

        us, n = timed(make_users)
        emit("sharing/users_created", us, n)

        cross_visible = 0
        cross_auth_ok = 0
        replica_cross_auth_ok = 0
        for i, si in enumerate(sessions):
            for j, sj in enumerate(sessions):
                if i == j:
                    continue
                try:
                    sj.server.store.get(si.token, f"home/private_{j}.dat")
                    cross_auth_ok += 1
                except (AuthError, FileNotFoundError):
                    pass
                # the replica fabric must not widen the trust boundary:
                # user i's token is worthless at user j's replica stores
                for rep in sj.replicas.replicas.values():
                    try:
                        rep.store.get(si.token, f"home/private_{j}.dat")
                        replica_cross_auth_ok += 1
                    except (AuthError, FileNotFoundError):
                        pass
                got = si.server.store.listdir(si.token, "home/")
                cross_visible += sum(1 for st in got
                                     if st.path == f"home/private_{j}.dat")
        emit("sharing/cross_user_reads", 0.0, cross_auth_ok)
        emit("sharing/cross_user_replica_reads", 0.0, replica_cross_auth_ok)
        emit("sharing/cross_user_listings", 0.0, cross_visible)
        leaks = cross_auth_ok + replica_cross_auth_ok + cross_visible
        emit("sharing/private_fraction", 0.0, 1.0 if leaks == 0 else 0.0)
        if leaks:
            print(f"FAIL: {leaks} cross-user leaks with replicas placed",
                  file=sys.stderr)
            failures += 1
    return failures


def _shared_mount_census(n_clients: int, n_files: int) -> int:
    """Many clients mount ONE home space; sweep cold reads with and
    without replica placement and report where the fills landed.  The
    owner logs in once; every further reader is a ``Fabric.attach`` —
    sharing a namespace is API, not copy-pasted wiring."""
    from repro.core import (
        Fabric, FabricSpec, LinkModel, LinkSpec, MountSpec, ReplicaPolicy,
        SiteSpec,
    )

    size = 32 * 1024
    failures = 0
    results = {}
    with tempfile.TemporaryDirectory() as td:
        for n_replicas in (0, 2):
            cnames = [f"csite{n_replicas}_{c}" for c in range(n_clients)]
            sites = [SiteSpec("proj_home", root=f"{td}/proj-{n_replicas}")]
            sites += [SiteSpec(f"pr{r}") for r in range(n_replicas)]
            sites += [SiteSpec(cn, root=f"{td}/c{n_replicas}-{cn}")
                      for cn in cnames]
            # replica sites sit near the clients; pin the home<->replica
            # path at the WAN default rather than the composition rule
            links = [LinkSpec("proj_home", f"pr{r}", latency_s=0.060)
                     for r in range(n_replicas)]
            links += [LinkSpec(cn, f"pr{r}", latency_s=0.004 * (r + 1))
                      for cn in cnames for r in range(n_replicas)]
            fab = Fabric(FabricSpec(sites=tuple(sites), links=tuple(links),
                                    link=LinkModel(latency_s=0.060)))
            mounts = (MountSpec("proj/"),)
            policy = ReplicaPolicy(
                sites=tuple(f"pr{r}" for r in range(n_replicas))) \
                if n_replicas else None
            owner = fab.login("proj", home="proj_home", site=cnames[0],
                              mounts=mounts, replicas=policy)
            store, token = owner.server.store, owner.token
            for i in range(n_files):
                store.put(token, f"proj/shared_{i}.dat", b"s" * size)
            if owner.replicas is not None:
                owner.replicas.resync()
            clients = [fab.attach(owner, cname, owner=f"reader{c}",
                                  mounts=mounts)
                       for c, cname in enumerate(cnames)]

            def sweep(clients=clients, net=fab.network):
                c0 = net.clock
                for cl in clients:
                    for i in range(n_files):
                        with cl.open(f"proj/shared_{i}.dat") as f:
                            assert len(f.read()) == size
                return net.clock - c0

            us, wan_s = timed(sweep)
            fills = cache_fill_totals(clients)
            home_fills = fills.get("proj_home", 0)
            rep_fills = sum(v for k, v in fills.items()
                            if k != "proj_home")
            offload = rep_fills / max(home_fills + rep_fills, 1)
            tag = f"replicas={n_replicas}"
            emit(f"sharing/shared_mount_{tag}_wan_s", us, f"{wan_s:.4f}")
            emit(f"sharing/shared_mount_{tag}_home_fills", 0.0, home_fills)
            emit(f"sharing/shared_mount_{tag}_replica_fills", 0.0,
                 rep_fills)
            emit(f"sharing/shared_mount_{tag}_offload_frac", 0.0,
                 f"{offload:.2f}")
            results[n_replicas] = (wan_s, offload)

    wan0, _ = results[0]
    wan2, offload2 = results[2]
    if not wan2 < wan0:
        print(f"FAIL: replica placement did not speed up the shared "
              f"namespace ({wan2:.4f}s vs home-only {wan0:.4f}s)",
              file=sys.stderr)
        failures += 1
    if offload2 <= 0.9:
        print(f"FAIL: replicas absorbed only {offload2:.0%} of shared "
              "fills", file=sys.stderr)
        failures += 1
    return failures


def _scale_net(trace_limit: int):
    from repro.core import LinkModel, Network

    return Network(link=LinkModel(latency_s=0.020),
                   channels_per_pair=SCALE_CHANNELS,
                   trace_limit=trace_limit)


def _census_nbytes(u: int, w: int) -> int:
    # deterministic per-(user, wave) sizes — no RNG, no seeds to drift
    return 8192 + (u * 37 + w * 101) % 57344


def _run_scale_census(net, n_users: int, waves: int, engine: str):
    """One census run against ``net``: ``waves`` rounds where every user
    first prices all candidate servers, then every chosen transfer is
    issued — estimates strictly before transfers within a round (the
    same-epoch rule), no clock advance between rounds (channel-queue
    feedback steers later rounds), one drain at the end.

    ``engine`` is ``"batched"`` (``estimate_batch`` + one
    ``transfer_batch`` per round) or ``"legacy"`` (the same algorithm
    through the scalar ``estimated_completion``/``transfer`` calls).
    Both engines make identical routing decisions, so their traces are
    bit-identical — that equivalence is the correctness witness.

    Returns ``(n_transfers, completions)`` — completion times since the
    epoch (clock 0), i.e. queue + wire latency per census transfer.
    """
    import numpy as np

    S = SCALE_SERVERS
    servers = [f"srv{k}" for k in range(S)]
    unames = [f"u{u}" for u in range(n_users)]
    net.prealloc(servers)
    # each user prices servers in its own rotation so equal estimates
    # (first round, idle fabric) spread the herd instead of piling on
    # srv0 — both engines scan the same per-user order
    rot = [[servers[(u + k) % S] for k in range(S)]
           for u in range(n_users)]
    n_transfers = 0
    comps = []

    if engine == "batched":
        from itertools import repeat

        cand_srcs = [rot[u][k] for u in range(n_users) for k in range(S)]
        cand_dsts = [unames[u] for u in range(n_users) for _ in range(S)]
        pair_ids = net.intern_pairs(cand_srcs, cand_dsts)
        pid_mat = pair_ids.reshape(n_users, S)
        row_idx = np.arange(n_users)
        u_arr = np.arange(n_users, dtype=np.int64)
        srv_arr = np.array(servers)
        for w in range(waves):
            nb = 8192 + (u_arr * 37 + w * 101) % 57344
            est = net.estimate_batch(
                cand_srcs, cand_dsts, np.repeat(nb, S),
                pair_ids=pair_ids).reshape(n_users, S)
            pick_arr = est.argmin(axis=1)
            # chosen server u = rot[u][pick[u]] = servers[(u + pick) % S]
            chosen = srv_arr[(u_arr + pick_arr) % S].tolist()
            batch = net.transfer_batch(
                list(zip(chosen, unames, repeat("census"), nb.tolist())),
                pair_ids=pid_mat[row_idx, pick_arr])
            comps.append(batch.completions)
            n_transfers += len(batch)
        net.drain()
        return n_transfers, np.concatenate(comps) if comps else np.zeros(0)

    for w in range(waves):
        choices = []
        for u in range(n_users):
            nb = _census_nbytes(u, w)
            best_s, best_e = None, None
            for s in rot[u]:
                e = net.estimated_completion(s, unames[u], nb)
                if best_e is None or e < best_e:
                    best_s, best_e = s, e
            choices.append(best_s)
        for u in range(n_users):
            t = net.transfer(choices[u], unames[u], "census",
                             _census_nbytes(u, w))
            comps.append(t.completion)
            n_transfers += 1
    net.drain()
    return n_transfers, np.asarray(comps)


def _scale_witness(n_users: int = 96, waves: int = 3) -> int:
    """Run BOTH engines on fresh networks and require bit-identical
    traces, clocks, and accounting — the batched engine must be an
    optimization, never a model change."""
    import numpy as np

    net_l = _scale_net(trace_limit=n_users * waves + 8)
    net_b = _scale_net(trace_limit=n_users * waves + 8)
    n_l, c_l = _run_scale_census(net_l, n_users, waves, "legacy")
    n_b, c_b = _run_scale_census(net_b, n_users, waves, "batched")
    ok = (n_l == n_b
          and net_l.trace == net_b.trace
          and net_l.clock == net_b.clock
          and net_l.bytes_sent == net_b.bytes_sent
          and dict(net_l.per_endpoint_bytes) == dict(net_b.per_endpoint_bytes)
          and dict(net_l.per_pair_rpcs) == dict(net_b.per_pair_rpcs)
          and np.array_equal(np.asarray(c_l), np.asarray(c_b)))
    emit("sharing/scale_trace_identical", 0.0, 1 if ok else 0)
    if not ok:
        print("FAIL: batched census trace diverged from the scalar "
              "engine", file=sys.stderr)
        return 1
    return 0


def _scale_speedup():
    """events/sec of both engines at the pinned 1k-user config on THIS
    machine; the ratio is the machine-normalized regression metric (so
    the committed baseline transfers across CI hardware).  The config
    — users, servers, waves — is pinned regardless of smoke trims so
    ratios stay comparable."""
    wall_l, (n_l, _c) = timed(
        lambda: _run_scale_census(_scale_net(0), RATIO_USERS, SCALE_WAVES,
                                  "legacy"))
    wall_b, (n_b, _c) = timed(
        lambda: _run_scale_census(_scale_net(0), RATIO_USERS, SCALE_WAVES,
                                  "batched"))
    eps_l = 2 * n_l / (wall_l / 1e6)
    eps_b = 2 * n_b / (wall_b / 1e6)
    return eps_l, eps_b, eps_b / eps_l


def _scale_census(n_users: int, *, smoke_scale: bool = False,
                  write_json: bool = True) -> int:
    """The 100k-user census gate: correctness witness, speedup ratio at
    1k, then the full batched run with wall-clock, events/sec, and
    latency percentiles.  Events are reservation + settlement per
    transfer (2 per).  ``--smoke-scale`` trims waves and skips the hard
    10x gate (CI timer noise) but keeps the baseline regression gate,
    which compares the machine-normalized speedup ratio."""
    failures = 0
    waves = 3 if smoke_scale else SCALE_WAVES

    failures += _scale_witness()
    eps_l, eps_b, speedup = _scale_speedup()
    emit("sharing/scale_1k_legacy_events_per_s", 0.0, f"{eps_l:.0f}")
    emit("sharing/scale_1k_batched_events_per_s", 0.0, f"{eps_b:.0f}")
    emit("sharing/scale_speedup_1k", 0.0, f"{speedup:.1f}")

    net = _scale_net(trace_limit=1000)
    wall_us, (n_transfers, comps) = timed(
        lambda: _run_scale_census(net, n_users, waves, "batched"))
    wall_s = wall_us / 1e6
    events = 2 * n_transfers
    eps = events / wall_s
    pct = percentiles(comps, qs=(50, 99))
    emit("sharing/scale_users", 0.0, n_users)
    emit("sharing/scale_wall_s", wall_us, f"{wall_s:.3f}")
    emit("sharing/scale_events_per_s", 0.0, f"{eps:.0f}")
    emit("sharing/scale_lat_p50_s", 0.0, f"{pct['p50']:.4f}")
    emit("sharing/scale_lat_p99_s", 0.0, f"{pct['p99']:.4f}")

    if write_json:
        write_bench_json(BENCH_JSON_PATH, {
            "users": n_users,
            "waves": waves,
            "servers": SCALE_SERVERS,
            "transfers": n_transfers,
            "events": events,
            "wall_s": round(wall_s, 4),
            "events_per_s": round(eps, 1),
            "lat_p50_s": round(pct["p50"], 6),
            "lat_p99_s": round(pct["p99"], 6),
            "ratio_users": RATIO_USERS,
            "legacy_1k_events_per_s": round(eps_l, 1),
            "batched_1k_events_per_s": round(eps_b, 1),
            "speedup_1k": round(speedup, 2),
            "smoke_scale": smoke_scale,
        })

    baseline = read_bench_json(BASELINE_PATH)
    if baseline is not None:
        floor = 0.8 * float(baseline["speedup_1k"])
        if speedup < floor:
            print(f"FAIL: census speedup regressed: {speedup:.1f}x vs "
                  f"baseline floor {floor:.1f}x "
                  f"(committed {baseline['speedup_1k']}x)",
                  file=sys.stderr)
            failures += 1
    if not smoke_scale and speedup < 10.0:
        print(f"FAIL: batched engine only {speedup:.1f}x the legacy "
              "scalar engine at the 1k-user config (gate: 10x)",
              file=sys.stderr)
        failures += 1
    return failures


def run(smoke: bool = False) -> int:
    n_users = SMOKE_USERS if smoke else N_USERS
    n_clients = SMOKE_CLIENTS if smoke else N_CLIENTS
    n_files = SMOKE_SHARED_FILES if smoke else N_SHARED_FILES
    failures = _private_census(n_users)
    failures += _shared_mount_census(n_clients, n_files)
    # modest-size scale census rides the standard sweep: the witness is
    # a hard gate, the perf gates live in the CLI path (timer noise)
    failures += _scale_witness()
    scale_users = SCALE_SMOKE_USERS if smoke else SCALE_USERS
    net = _scale_net(trace_limit=1000)
    wall_us, (n_transfers, comps) = timed(
        lambda: _run_scale_census(net, scale_users, SCALE_WAVES, "batched"))
    pct = percentiles(comps, qs=(50, 99))
    emit("sharing/scale_users", 0.0, scale_users)
    emit("sharing/scale_events_per_s", wall_us,
         f"{2 * n_transfers / (wall_us / 1e6):.0f}")
    emit("sharing/scale_lat_p50_s", 0.0, f"{pct['p50']:.4f}")
    emit("sharing/scale_lat_p99_s", 0.0, f"{pct['p99']:.4f}")
    return 1 if failures else 0


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small privacy/shared-mount census")
    ap.add_argument("--users", type=int, default=None,
                    help="run ONLY the scale census at this many users "
                         "(witness + speedup ratio + BENCH_census.json)")
    ap.add_argument("--smoke-scale", action="store_true",
                    help="scale census with fewer waves and no hard 10x "
                         "gate; the baseline regression gate still runs")
    args = ap.parse_args()
    if args.users is not None or args.smoke_scale:
        rc = 1 if _scale_census(args.users or 100_000,
                                smoke_scale=args.smoke_scale) else 0
        if rc == 0:
            print("sharing_census: OK (batched census trace-identical "
                  "to scalar; perf gates passed)")
    else:
        rc = run(smoke=args.smoke)
        if rc == 0:
            print("sharing_census: OK (private with replicas placed; "
                  "shared mounts offload to replica sites)")
    raise SystemExit(rc)
