"""Paper §2.2: file-sharing census — private-by-default namespaces.

The paper found 1 of 1,964 users shared files.  XUFS's answer is private
per-user namespaces: this benchmark creates N user sessions against one
network and verifies (a) zero cross-user object visibility, (b) zero
cross-user auth-token validity, and reports the census.
"""
from __future__ import annotations

import tempfile

from benchmarks.common import emit, timed

N_USERS = 32
SMOKE_USERS = 6                   # census check is O(n^2)


def run(smoke: bool = False) -> None:
    from repro.core import Network, ussh_login, AuthError

    n_users = SMOKE_USERS if smoke else N_USERS
    with tempfile.TemporaryDirectory() as td:
        net = Network()
        sessions = []

        def make_users():
            for i in range(n_users):
                s = ussh_login(f"user{i}", net, f"{td}/h{i}", f"{td}/s{i}",
                               home_name=f"home{i}", site_name=f"site{i}")
                s.server.store.put(s.token, f"home/private_{i}.dat",
                                   b"secret" * 100)
                sessions.append(s)
            return len(sessions)

        us, n = timed(make_users)
        emit("sharing/users_created", us, n)

        cross_visible = 0
        cross_auth_ok = 0
        for i, si in enumerate(sessions):
            for j, sj in enumerate(sessions):
                if i == j:
                    continue
                try:
                    sj.server.store.get(si.token, f"home/private_{j}.dat")
                    cross_auth_ok += 1
                except (AuthError, FileNotFoundError):
                    pass
                got = si.server.store.listdir(si.token, "home/")
                cross_visible += sum(1 for st in got
                                     if st.path == f"home/private_{j}.dat")
        emit("sharing/cross_user_reads", 0.0, cross_auth_ok)
        emit("sharing/cross_user_listings", 0.0, cross_visible)
        emit("sharing/private_fraction", 0.0,
             1.0 if (cross_auth_ok + cross_visible) == 0 else 0.0)
