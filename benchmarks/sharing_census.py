"""Paper §2.2: file-sharing census — private-by-default namespaces.

The paper found 1 of 1,964 users shared files.  XUFS's answer is private
per-user namespaces; the replica fabric must not widen that.  Two parts:

  * **Private census** (the original): N user sessions — now each with
    read replicas placed — against one network.  Verifies (a) zero
    cross-user object visibility, (b) zero cross-user auth-token
    validity *including against every replica store* (a replica of a
    private home space is as private as the home), and reports the
    census.

  * **Shared-mount census** (replica placement): many clients mount the
    SAME home space (the paper's shared project data case).  With no
    replicas every cold read hammers the far home link; with replicas
    placed, fills route to near replica sites.  Reports where the fills
    landed (`home_fills` vs `replica_fills`), the offload fraction, and
    the modeled WAN time for the sweep — how placement changes the
    sharing picture.

Run standalone, exits non-zero if privacy is violated or if replica
placement fails to serve a shared namespace faster than home-only.
"""
from __future__ import annotations

import os
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import cache_fill_totals, emit, timed

N_USERS = 16                      # census check is O(n^2)
SMOKE_USERS = 4
N_CLIENTS = 8                     # shared-mount readers
SMOKE_CLIENTS = 3
N_SHARED_FILES = 12
SMOKE_SHARED_FILES = 4


def _private_census(n_users: int) -> int:
    """N private namespaces on ONE declared fabric: the whole multi-user
    topology is a single FabricSpec, and each user is one login."""
    from repro.core import (
        AuthError, Fabric, FabricSpec, LinkModel, LinkSpec, ReplicaPolicy,
        SiteSpec,
    )

    failures = 0
    with tempfile.TemporaryDirectory() as td:
        sites, links = [], []
        for i in range(n_users):
            sites += [SiteSpec(f"home{i}", root=f"{td}/h{i}"),
                      SiteSpec(f"site{i}", root=f"{td}/s{i}"),
                      SiteSpec(f"u{i}r1"), SiteSpec(f"u{i}r2")]
            links += [LinkSpec(f"site{i}", f"u{i}r1", latency_s=0.005),
                      LinkSpec(f"site{i}", f"u{i}r2", latency_s=0.015)]
        fab = Fabric(FabricSpec(sites=tuple(sites), links=tuple(links),
                                link=LinkModel(latency_s=0.060)))
        sessions = []

        def make_users():
            for i in range(n_users):
                s = fab.login(
                    f"user{i}", home=f"home{i}", site=f"site{i}",
                    replicas=ReplicaPolicy(sites=(f"u{i}r1", f"u{i}r2")))
                s.server.store.put(s.token, f"home/private_{i}.dat",
                                   b"secret" * 100)
                s.replicas.resync()          # private bytes now replicated
                sessions.append(s)
            return len(sessions)

        us, n = timed(make_users)
        emit("sharing/users_created", us, n)

        cross_visible = 0
        cross_auth_ok = 0
        replica_cross_auth_ok = 0
        for i, si in enumerate(sessions):
            for j, sj in enumerate(sessions):
                if i == j:
                    continue
                try:
                    sj.server.store.get(si.token, f"home/private_{j}.dat")
                    cross_auth_ok += 1
                except (AuthError, FileNotFoundError):
                    pass
                # the replica fabric must not widen the trust boundary:
                # user i's token is worthless at user j's replica stores
                for rep in sj.replicas.replicas.values():
                    try:
                        rep.store.get(si.token, f"home/private_{j}.dat")
                        replica_cross_auth_ok += 1
                    except (AuthError, FileNotFoundError):
                        pass
                got = si.server.store.listdir(si.token, "home/")
                cross_visible += sum(1 for st in got
                                     if st.path == f"home/private_{j}.dat")
        emit("sharing/cross_user_reads", 0.0, cross_auth_ok)
        emit("sharing/cross_user_replica_reads", 0.0, replica_cross_auth_ok)
        emit("sharing/cross_user_listings", 0.0, cross_visible)
        leaks = cross_auth_ok + replica_cross_auth_ok + cross_visible
        emit("sharing/private_fraction", 0.0, 1.0 if leaks == 0 else 0.0)
        if leaks:
            print(f"FAIL: {leaks} cross-user leaks with replicas placed",
                  file=sys.stderr)
            failures += 1
    return failures


def _shared_mount_census(n_clients: int, n_files: int) -> int:
    """Many clients mount ONE home space; sweep cold reads with and
    without replica placement and report where the fills landed.  The
    owner logs in once; every further reader is a ``Fabric.attach`` —
    sharing a namespace is API, not copy-pasted wiring."""
    from repro.core import (
        Fabric, FabricSpec, LinkModel, LinkSpec, MountSpec, ReplicaPolicy,
        SiteSpec,
    )

    size = 32 * 1024
    failures = 0
    results = {}
    with tempfile.TemporaryDirectory() as td:
        for n_replicas in (0, 2):
            cnames = [f"csite{n_replicas}_{c}" for c in range(n_clients)]
            sites = [SiteSpec("proj_home", root=f"{td}/proj-{n_replicas}")]
            sites += [SiteSpec(f"pr{r}") for r in range(n_replicas)]
            sites += [SiteSpec(cn, root=f"{td}/c{n_replicas}-{cn}")
                      for cn in cnames]
            # replica sites sit near the clients; pin the home<->replica
            # path at the WAN default rather than the composition rule
            links = [LinkSpec("proj_home", f"pr{r}", latency_s=0.060)
                     for r in range(n_replicas)]
            links += [LinkSpec(cn, f"pr{r}", latency_s=0.004 * (r + 1))
                      for cn in cnames for r in range(n_replicas)]
            fab = Fabric(FabricSpec(sites=tuple(sites), links=tuple(links),
                                    link=LinkModel(latency_s=0.060)))
            mounts = (MountSpec("proj/"),)
            policy = ReplicaPolicy(
                sites=tuple(f"pr{r}" for r in range(n_replicas))) \
                if n_replicas else None
            owner = fab.login("proj", home="proj_home", site=cnames[0],
                              mounts=mounts, replicas=policy)
            store, token = owner.server.store, owner.token
            for i in range(n_files):
                store.put(token, f"proj/shared_{i}.dat", b"s" * size)
            if owner.replicas is not None:
                owner.replicas.resync()
            clients = [fab.attach(owner, cname, owner=f"reader{c}",
                                  mounts=mounts)
                       for c, cname in enumerate(cnames)]

            def sweep(clients=clients, net=fab.network):
                c0 = net.clock
                for cl in clients:
                    for i in range(n_files):
                        with cl.open(f"proj/shared_{i}.dat") as f:
                            assert len(f.read()) == size
                return net.clock - c0

            us, wan_s = timed(sweep)
            fills = cache_fill_totals(clients)
            home_fills = fills.get("proj_home", 0)
            rep_fills = sum(v for k, v in fills.items()
                            if k != "proj_home")
            offload = rep_fills / max(home_fills + rep_fills, 1)
            tag = f"replicas={n_replicas}"
            emit(f"sharing/shared_mount_{tag}_wan_s", us, f"{wan_s:.4f}")
            emit(f"sharing/shared_mount_{tag}_home_fills", 0.0, home_fills)
            emit(f"sharing/shared_mount_{tag}_replica_fills", 0.0,
                 rep_fills)
            emit(f"sharing/shared_mount_{tag}_offload_frac", 0.0,
                 f"{offload:.2f}")
            results[n_replicas] = (wan_s, offload)

    wan0, _ = results[0]
    wan2, offload2 = results[2]
    if not wan2 < wan0:
        print(f"FAIL: replica placement did not speed up the shared "
              f"namespace ({wan2:.4f}s vs home-only {wan0:.4f}s)",
              file=sys.stderr)
        failures += 1
    if offload2 <= 0.9:
        print(f"FAIL: replicas absorbed only {offload2:.0%} of shared "
              "fills", file=sys.stderr)
        failures += 1
    return failures


def run(smoke: bool = False) -> int:
    n_users = SMOKE_USERS if smoke else N_USERS
    n_clients = SMOKE_CLIENTS if smoke else N_CLIENTS
    n_files = SMOKE_SHARED_FILES if smoke else N_SHARED_FILES
    failures = _private_census(n_users)
    failures += _shared_mount_census(n_clients, n_files)
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("sharing_census: OK (private with replicas placed; shared "
              "mounts offload to replica sites)")
    raise SystemExit(rc)
