"""Bulk-transfer plane: adaptive streams + third-party movement (gated).

Four self-gating claims (ISSUE 10 acceptance criteria):

  A. **Adaptive beats fixed.**  A checkpoint-sized (GB-scale) transfer
     over a high-BDP link drains strictly faster under the AIMD
     planner (``BulkTransfer``, seeded at the BDP grant) than through
     the legacy fixed 12-stream pool: 12 window-limited streams cap
     the pair at ``12 x per_stream_bw`` while the grant fills the link.
  B. **Third-party repair drains faster.**  With two stale replicas and
     equal NIC budgets everywhere, the maintenance scheduler's
     ``repair:`` family drains strictly faster when applies pull from
     the cheapest fresh *replica* (queue-aware source spread across
     home + r1) than when every byte serializes through home.
  C. **Read repair comes off the client's NIC.**  A client reading a
     stale-replica working set repairs it via third-party pulls: the
     drain is strictly faster and the client endpoint's busy-seconds
     measurably lower than the client-mediated push path.
  D. **Spec-unset is free.**  The same mixed workload with ``bulk``
     unset and with a neutral fixed-width spec (``max_streams=12``,
     ``adapt=False``, ``third_party=False``) produces bit-identical
     transport traces.
"""
from __future__ import annotations

import dataclasses
import sys
import tempfile

from benchmarks.common import (
    emit, emit_byte_provenance, emit_endpoint_utilization, timed,
)

REPLICA_LATENCIES = {"r1": 0.005, "r2": 0.015, "r3": 0.025}
HIGH_BDP_LATENCY = 0.060
PATHS = "home/data/part{:02d}.bin"


def _bulk_specs(smoke: bool):
    from repro.core import MB, BulkSpec

    probe = (4 if smoke else 32) * MB
    fixed = BulkSpec(min_streams=12, max_streams=12, adapt=False,
                     third_party=False)
    adaptive = BulkSpec(max_streams=64, probe_bytes=probe)
    neutral = BulkSpec(min_streams=1, max_streams=12, adapt=False,
                       third_party=False)
    third_party = BulkSpec(min_streams=1, max_streams=12, adapt=False,
                           third_party=True)
    return fixed, adaptive, neutral, third_party


# ---- A: adaptive vs fixed on one high-BDP pair ------------------------------

def _gb_drain(spec, nbytes):
    from repro.core import BulkTransfer, Endpoint, LinkModel, Network

    net = Network(link=LinkModel(latency_s=HIGH_BDP_LATENCY))
    Endpoint("a", net)
    Endpoint("b", net)
    return BulkTransfer(net, spec).push("a", "b", nbytes)


def _login(tmp, tag, bulk, *, maintenance=None):
    from repro.core import Fabric, FabricSpec, LinkModel, ReplicaPolicy

    spec = FabricSpec.star(f"{tmp}/home-{tag}", f"{tmp}/site-{tag}",
                           replica_latencies=REPLICA_LATENCIES,
                           link=LinkModel(latency_s=HIGH_BDP_LATENCY))
    if maintenance is not None:
        spec = dataclasses.replace(spec, maintenance=maintenance)
    return Fabric(spec).login("sci", replicas=ReplicaPolicy(
        sites=tuple(REPLICA_LATENCIES), bulk=bulk))


def _stale_replicas(s, n_paths, size, targets=("r2", "r3")):
    """Seed every replica, then land a new version that only the
    non-target replicas see: ``targets`` end lagging on every path."""
    net = s.client.network
    payload_v1 = b"a" * size
    payload_v2 = b"b" * size
    for i in range(n_paths):
        s.server.store.put(s.token, PATHS.format(i), payload_v1)
    s.replicas.resync()
    for i in range(n_paths):
        s.server.store.put(s.token, PATHS.format(i), payload_v2)
    sources = [ep for ep in ("home", "r1", "r2", "r3") if ep not in targets]
    for t in targets:
        for src in sources + [x for x in targets if x != t]:
            net.partition(src, t)
    s.replicas.resync()
    for t in targets:
        for src in sources + [x for x in targets if x != t]:
            net.heal(src, t)
    for t in targets:
        lag = s.replicas.replicas[t].lagging
        assert all(PATHS.format(i) in lag for i in range(n_paths)), \
            f"{t} not lagging as arranged"
    return payload_v2


def _arm_budgets(net, budget, endpoints=("home", "site", "r1", "r2", "r3")):
    for ep in endpoints:
        net.set_nic_budget(ep, budget)


# ---- B: scheduled repair drain, third-party vs home-mediated ----------------

def _repair_drain(tmp, tag, bulk, n_paths, size, budget):
    from repro.core import MaintenanceSpec

    maint = MaintenanceSpec(resync_period_s=10_000.0,
                            repair_period_s=1.0,
                            lease_period_s=10_000.0,
                            reconcile_period_s=10_000.0)
    s = _login(tmp, tag, bulk, maintenance=maint)
    net = s.client.network
    _stale_replicas(s, n_paths, size)
    _arm_budgets(net, budget)
    t0 = net.clock
    s.scheduler.run_until(t0 + 1.1)       # one repair tick launches all
    s.scheduler.quiesce()
    return s, net.clock - t0


# ---- C: read-repair offload, third-party vs client-mediated -----------------

def _read_repair_drain(tmp, tag, bulk, n_paths, size, budget):
    s = _login(tmp, tag, bulk)
    net = s.client.network
    payload = _stale_replicas(s, n_paths, size, targets=("r2",))
    _arm_budgets(net, budget)
    t0 = net.clock
    for i in range(n_paths):
        with s.client.open(PATHS.format(i)) as f:
            assert f.read() == payload
    net.drain()
    return s, net.clock - t0, net.per_endpoint_busy_s.get("site", 0.0)


# ---- D: spec-unset identity -------------------------------------------------

def _identity_trace(tmp, tag, bulk, size):
    from repro.core import MB

    s = _login(tmp, tag, bulk)
    net = s.client.network
    payload = _stale_replicas(s, 2, size, targets=("r2",))
    for i in range(2):
        with s.client.open(PATHS.format(i)) as f:
            assert f.read() == payload
    for p in s.replicas.begin_repair_path(PATHS.format(0)):
        net.wait(p.ack)
        s.replicas.complete_apply(p)
    with s.client.open("home/data/out.bin", "w") as f:
        f.write(b"c" * (2 * MB))
    s.client.sync()
    net.drain()
    return list(net.trace)


def run(smoke: bool = False) -> int:
    from repro.core import GB, MB

    fixed, adaptive, neutral, third_party = _bulk_specs(smoke)
    gb = 64 * MB if smoke else 1 * GB
    n_paths = 3 if smoke else 6
    # Smoke payloads must still overrun the repair tick's 1.1 s scheduler
    # window through home's NIC, else both drains report the window floor.
    size = (16 if smoke else 32) * MB
    budget = (80 if smoke else 150) * MB
    failures = []

    # -- A ------------------------------------------------------------------
    us_f, fixed_res = timed(lambda: _gb_drain(fixed, gb).elapsed_s)
    us_a, adapt_res = timed(lambda: _gb_drain(adaptive, gb).elapsed_s)
    emit("bulk/fixed12_drain_s", us_f, f"{fixed_res:.4f}")
    emit("bulk/adaptive_drain_s", us_a, f"{adapt_res:.4f}")
    widths = _gb_drain(adaptive, gb).widths
    emit("bulk/adaptive_widths", 0.0, ";".join(map(str, widths)))
    if not adapt_res < fixed_res:
        failures.append(
            f"adaptive drain {adapt_res:.4f}s not strictly under fixed-12 "
            f"{fixed_res:.4f}s")

    # -- B ------------------------------------------------------------------
    with tempfile.TemporaryDirectory() as tmp:
        s_cm, cm_drain = _repair_drain(tmp, "repair-cm", None,
                                       n_paths, size, budget)
        s_tp, tp_drain = _repair_drain(tmp, "repair-tp", third_party,
                                       n_paths, size, budget)
        emit("bulk/repair_drain_mediated_s", 0.0, f"{cm_drain:.4f}")
        emit("bulk/repair_drain_third_party_s", 0.0, f"{tp_drain:.4f}")
        emit_byte_provenance("bulk/repair_tp", s_tp.client.network)
        if not tp_drain < cm_drain:
            failures.append(
                f"third-party repair drain {tp_drain:.4f}s not strictly "
                f"under home-mediated {cm_drain:.4f}s at equal budgets")
        if s_tp.replicas.third_party_pulls == 0:
            failures.append("third-party repair drain made no replica pulls")

        # -- C --------------------------------------------------------------
        s_cm, cm_drain, cm_busy = _read_repair_drain(
            tmp, "read-cm", None, n_paths, size, budget)
        s_tp, tp_drain, tp_busy = _read_repair_drain(
            tmp, "read-tp", third_party, n_paths, size, budget)
        emit("bulk/read_repair_mediated_s", 0.0,
             f"{cm_drain:.4f};client_busy_s={cm_busy:.4f}")
        emit("bulk/read_repair_third_party_s", 0.0,
             f"{tp_drain:.4f};client_busy_s={tp_busy:.4f}")
        emit_byte_provenance("bulk/read_cm", s_cm.client.network)
        emit_byte_provenance("bulk/read_tp", s_tp.client.network)
        emit_endpoint_utilization("bulk/read_tp", s_tp.client.network,
                                  endpoints=["site", "home", "r1", "r2"])
        if not tp_drain < cm_drain:
            failures.append(
                f"third-party read-repair drain {tp_drain:.4f}s not "
                f"strictly under client-mediated {cm_drain:.4f}s")
        if not tp_busy < 0.8 * cm_busy:
            failures.append(
                f"client NIC busy {tp_busy:.4f}s not measurably under "
                f"client-mediated {cm_busy:.4f}s")
        if s_cm.client.network.bytes_client_mediated == 0:
            failures.append("mediated run recorded no client-mediated bytes")
        if s_tp.client.network.bytes_third_party == 0:
            failures.append("third-party run recorded no third-party bytes")

        # -- D --------------------------------------------------------------
        base = _identity_trace(tmp, "ident-unset", None, size)
        spec = _identity_trace(tmp, "ident-neutral", neutral, size)
        identical = int(base == spec)
        emit("bulk/spec_unset_trace_identical", 0.0, identical)
        if not identical:
            failures.append("neutral BulkSpec trace differs from spec-unset")

    for f in failures:
        print(f"FAIL(fig_bulk): {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(run())
