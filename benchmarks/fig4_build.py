"""Paper Fig 4 (source-tree build): many small files, consecutive runs.

24 files / ~12k LOC / 5 subdirectories, mostly <64 KB — exactly the
paper's workload.  Run 1 pays the (parallel-prefetched) cold fetch; runs
2..5 are all cache hits.  The no-prefetch variant fetches serially on
first open, which is what the paper beats.
"""
from __future__ import annotations

import tempfile

from benchmarks.common import emit, star_fabric, timed

N_FILES = 24
SUBDIRS = 5
LINES = 12000


def _populate(s):
    per_file = LINES // N_FILES
    line = b"int f(int x) { return x * 2654435761u; }\n"
    for i in range(N_FILES):
        sub = f"d{i % SUBDIRS}"
        body = line * per_file
        s.server.store.put(s.token, f"home/src/{sub}/file{i}.c", body)


def _build_pass(s, net):
    """cd + read every source file + write one object file per source."""
    c0 = net.clock
    s.client.chdir("home/src")
    for e in s.client.listdir_cached("home/src"):
        if not e.path.endswith(".c"):
            continue
        with s.client.open(e.path) as f:
            src = f.read()
        obj = e.path.replace(".c", ".o")
        with s.client.open(obj, "w") as f:
            f.write(src[: len(src) // 2])
    return net.clock - c0


def run(smoke: bool = False) -> None:
    from repro.core import prefetch as pf_mod

    n_runs = 2 if smoke else 5    # run 1 cold, the rest warm cache hits
    # ---- with parallel prefetch (XUFS default) --------------------------
    with tempfile.TemporaryDirectory() as td:
        fab = star_fabric(td + "/h", td + "/s")
        net = fab.network
        s = fab.login("bench")
        _populate(s)
        for run_i in range(1, n_runs + 1):
            us, wan_s = timed(lambda: _build_pass(s, net))
            emit(f"fig4/build_run{run_i}_wan_s", us, round(wan_s, 4))
        s.client.sync()

    # ---- without prefetch (serial first-open fetches) --------------------
    with tempfile.TemporaryDirectory() as td:
        fab = star_fabric(td + "/h", td + "/s")
        net = fab.network
        s = fab.login("bench")
        _populate(s)
        old = pf_mod.Prefetcher.prefetch_small
        pf_mod.Prefetcher.prefetch_small = lambda self, p, st: 0
        try:
            us, wan_s = timed(lambda: _build_pass(s, net))
            emit("fig4/build_run1_noprefetch_wan_s", us, round(wan_s, 4))
        finally:
            pf_mod.Prefetcher.prefetch_small = old
