"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` measures the real
fabric code; ``derived`` is the modeled figure-of-merit (virtual-WAN
seconds / MB/s / fractions), deterministic across runs.
"""
from __future__ import annotations

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    print("name,us_per_call,derived")
    from benchmarks import (
        table1_sizes, fig23_iozone, fig4_build, fig5_largefile,
        fig_replica_read, sharing_census, roofline,
    )

    rc = 0
    for mod in (table1_sizes, fig23_iozone, fig4_build, fig5_largefile,
                fig_replica_read, sharing_census, roofline):
        rc |= int(mod.run() or 0)   # self-checking benchmarks gate the run
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
