"""Benchmark driver: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  ``us_per_call`` measures the real
fabric code; ``derived`` is the modeled figure-of-merit (virtual-WAN
seconds / MB/s / fractions), deterministic across runs.

``--smoke`` runs every module at tiny sizes (CI's benchmark job uses it to
keep the scripts from rotting without paying full-size runtimes).
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, no figure output (CI fast path)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    from benchmarks import (
        table1_sizes, fig23_iozone, fig4_build, fig5_largefile,
        fig_replica_read, fig_quorum_write, fig_congestion,
        fig_maintenance, fig_conflict, fig_eviction, fig_bulk,
        sharing_census, roofline,
    )

    rc = 0
    for mod in (table1_sizes, fig23_iozone, fig4_build, fig5_largefile,
                fig_replica_read, fig_quorum_write, fig_congestion,
                fig_maintenance, fig_conflict, fig_eviction, fig_bulk,
                sharing_census, roofline):
        rc |= int(mod.run(smoke=args.smoke) or 0)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
