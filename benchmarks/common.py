"""Shared benchmark scaffolding: timed runs + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows — ``us_per_call``
is measured wall time of the fabric code; ``derived`` is the modeled
quantity the paper's figure reports (seconds on the virtual WAN clock,
MB/s, etc.).
"""
from __future__ import annotations

import os
import sys
import time
from typing import Callable, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def timed(fn: Callable[[], float]) -> Tuple[float, float]:
    t0 = time.perf_counter()
    derived = fn()
    return (time.perf_counter() - t0) * 1e6, derived


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
