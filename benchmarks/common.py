"""Shared benchmark scaffolding: timed runs + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows — ``us_per_call``
is measured wall time of the fabric code; ``derived`` is the modeled
quantity the paper's figure reports (seconds on the virtual WAN clock,
MB/s, etc.).
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Callable, Dict, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def star_fabric(home_root: str, site_root: str, *, home: str = "home",
                site: str = "site", latency_s: Optional[float] = None,
                replica_latencies: Optional[Dict[str, float]] = None,
                nic_budgets: Optional[Dict[str, float]] = None,
                extra_sites=(), extra_links=()):
    """The benchmarks' canonical topology as a declarative spec: one
    compute ``site``, one ``home`` behind ``latency_s``, and replica
    sites at their site-relative latencies (the home<->replica path is
    left to the fabric's latency-composition rule).  ``extra_sites`` /
    ``extra_links`` graft incast clients and the like onto the star.
    Returns the built ``Fabric``; callers pass a ``ReplicaPolicy`` to
    ``fabric.login`` themselves — policy is theirs, topology is this.
    """
    from repro.core import Fabric, FabricSpec, LinkModel

    link = LinkModel() if latency_s is None else LinkModel(latency_s=latency_s)
    return Fabric(FabricSpec.star(home_root, site_root, home=home, site=site,
                                  replica_latencies=replica_latencies,
                                  nic_budgets=nic_budgets, link=link,
                                  extra_sites=extra_sites,
                                  extra_links=extra_links))


def timed(fn: Callable[[], float]) -> Tuple[float, float]:
    t0 = time.perf_counter()
    derived = fn()
    return (time.perf_counter() - t0) * 1e6, derived


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")


def percentiles(values, qs=(50, 99)) -> Dict[str, float]:
    """``{"p50": ..., "p99": ...}`` over a sequence of floats (numpy
    linear interpolation); empty input yields zeros so reporting code
    never branches."""
    import numpy as np

    arr = np.asarray(values, dtype=float)
    if arr.size == 0:
        return {f"p{q}": 0.0 for q in qs}
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


def write_bench_json(path: str, payload: dict) -> None:
    """Machine-readable benchmark record (sorted keys, trailing newline
    — byte-stable for identical payloads, diffable in CI artifacts)."""
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")


def read_bench_json(path: str) -> Optional[dict]:
    """Committed baseline loader; ``None`` when absent so first runs on
    a fresh checkout report instead of failing."""
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def cache_fill_totals(clients) -> Dict[str, int]:
    """Aggregate fills-by-source over many clients through the typed
    ``CacheSpace.stats()`` snapshot — reporting reads the snapshot, not
    the cache's raw dicts."""
    totals: Dict[str, int] = {}
    for cl in clients:
        for src, n in cl.cache.stats().fills_from.items():
            totals[src] = totals.get(src, 0) + n
    return totals


def emit_cache_stats(prefix: str, cache) -> None:
    """One ``<prefix>/cache`` row from a :class:`CacheStats` snapshot:
    hit rate, total fills, and live resident bytes."""
    st = cache.stats()
    emit(f"{prefix}/cache", 0.0,
         f"hit_rate={st.hit_rate:.2f};fills={st.fills};"
         f"resident={st.bytes_resident}")


def endpoint_utilization(net) -> Dict[str, Tuple[float, float, int]]:
    """Per-endpoint ``(channel_busy_s, busy_fraction, bytes)``.

    ``channel_busy_s`` sums every reservation's wire occupancy at both
    ends; the fraction divides by the current virtual clock and can
    exceed 1.0 when channels overlap — the excess IS the fan-out win,
    while a budgeted endpoint pinned near 1.0 is NIC-bound.
    """
    out: Dict[str, Tuple[float, float, int]] = {}
    horizon = net.clock
    eps = set(net.per_endpoint_bytes) | set(net.per_endpoint_busy_s)
    for ep in sorted(eps):
        busy = net.per_endpoint_busy_s.get(ep, 0.0)
        frac = busy / horizon if horizon > 0 else 0.0
        out[ep] = (busy, frac, net.per_endpoint_bytes.get(ep, 0))
    return out


def emit_byte_provenance(prefix: str, net) -> None:
    """One ``<prefix>/provenance`` row: replica-apply payload bytes by
    source class — third-party (storage->storage movement) vs
    client-mediated (pushed off a client session's NIC).  The bulk
    plane's offload witness (docs/maintenance.md)."""
    emit(f"{prefix}/provenance", 0.0,
         f"third_party={net.bytes_third_party};"
         f"client_mediated={net.bytes_client_mediated}")


def emit_endpoint_utilization(prefix: str, net,
                              endpoints: Optional[list] = None) -> None:
    """One ``<prefix>/util_<endpoint>`` row per endpoint: busy channel
    seconds, busy fraction of the virtual clock, and bytes moved —
    the per-endpoint companion to the per-pair rpc/byte counters."""
    util = endpoint_utilization(net)
    for ep, (busy, frac, nbytes) in util.items():
        if endpoints is not None and ep not in endpoints:
            continue
        emit(f"{prefix}/util_{ep}", 0.0,
             f"busy_s={busy:.4f};busy_frac={frac:.2f};bytes={nbytes}")
