"""Capacity-aware replica placement & eviction (GridFTP replica line).

A replica near the readers holding only ~10% of the home space must
still capture the large majority of read traffic: the working set is
what matters, not the mirror (SCISPACE's geo-workspace observation).
Four self-gating scenarios on the virtual WAN clock:

  A. **Capacity census.**  A home space of N objects; a replica bounded
     at ~10% of the bytes by an ``EvictionSpec``; waves of attach
     readers sweep a rotating hot set while the scheduled ``evict:``
     task trims between phases.  Gates: replica serves the majority of
     fills; ``peak_resident_bytes`` never exceeds ``capacity``; the
     scheduler actually evicted between phases.
  B. **Evict/repair share one LockTable.**  An eviction takes the
     per-path lease; the same path is rewritten during a partition and
     becomes a repair target while the evictor's lease is live.  Gates:
     ``double_repairs == 0``, the contention is a counted
     ``lock_conflicts``, and the path converges after the lease expires.
  C. **Quorum-parked bytes are not eviction fodder.**  A majority write
     assembled around a dead home parks at the replicas — the only
     durable copies.  The evict scan runs far over the high watermark.
     Gates: zero parked paths evicted; the freshness floor holds.
  D. **Zero-cost guarantee.**  Eviction unset ⇒ the transport trace is
     bit-identical to a fabric with no maintenance plane at all; the
     deprecated ``capacity_bytes=`` alias wires bit-identically to the
     explicit ``EvictionSpec``.

Rows:

  eviction/replica_capture_frac       scenario A (> 0.5 gated)
  eviction/peak_resident_frac         scenario A (<= 1.0 gated)
  eviction/scheduled_evictions        scenario A (> 0 gated)
  eviction/admission_refusals         scenario A, observability
  eviction/lock_conflicts             scenario B (> 0 gated)
  eviction/double_repairs             scenario B (== 0 gated)
  eviction/parked_evicted             scenario C (== 0 gated)
  eviction/unset_trace_identical      scenario D
  eviction/alias_trace_identical      scenario D
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import cache_fill_totals, emit, star_fabric, timed

HOME_LATENCY = 0.060


def _armed_fabric(home_root, site_root, *, replica_latencies,
                  extra_sites=(), maintenance=None):
    import dataclasses

    from repro.core import Fabric, MaintenanceSpec

    fab = star_fabric(home_root, site_root, latency_s=HOME_LATENCY,
                      replica_latencies=replica_latencies,
                      extra_sites=extra_sites)
    spec = dataclasses.replace(fab.spec,
                               maintenance=maintenance or MaintenanceSpec())
    return Fabric(spec)


# ---- scenario A: 10%-capacity replica still captures the traffic -----------

def _capacity_census(root: str, n_files: int, size: int, readers: int):
    """Rotating hot-set sweep against a replica capped at ~10% bytes.

    Each phase, fresh attach readers sweep that phase's hot set: the
    first touch of a path fills from home and demand-places it at the
    replica (read repair IS placement); every further reader fills from
    the replica.  Between phases the scheduled evict task trims the
    now-cold set, so the next hot set has room.
    """
    from repro.core import (EvictionSpec, MaintenanceSpec, MountSpec,
                            ReplicaPolicy)

    hot_per_phase = 3
    phases = 3
    capacity = (n_files * size) // 10            # ~10% of home-space bytes
    assert hot_per_phase * size <= capacity
    # high watermark below one hot set's bytes (0.75 cap): the scan
    # between phases always trims; low leaves room for the next hot set
    ev = EvictionSpec(capacity=capacity, high_watermark=0.7,
                      low_watermark=0.25, scan_period_s=5.0)
    spec = MaintenanceSpec(resync_period_s=1e6, repair_period_s=1e6,
                           lease_period_s=1e6, reconcile_period_s=1e6)
    fab = _armed_fabric(f"{root}/home-cc", f"{root}/site-cc",
                        replica_latencies={"r1": 0.005}, maintenance=spec)
    s = fab.login("owner", replicas=ReplicaPolicy(sites=("r1",),
                                                  eviction=ev))
    for i in range(n_files):
        s.server.store.put(s.token, f"home/data/f{i}.bin",
                           bytes([65 + i % 26]) * size)
    rep = s.replicas.replicas["r1"]
    clients = []
    for phase in range(phases):
        hot = [f"home/data/f{i}.bin"
               for i in range(phase * hot_per_phase,
                              (phase + 1) * hot_per_phase)]
        for c in range(readers):
            cl = fab.attach(s, "site", owner=f"p{phase}r{c}",
                            mounts=[MountSpec("home/")])
            clients.append(cl)
            for p in hot:
                with cl.open(p) as f:
                    assert len(f.read()) == size
        # think time between phases: the evict task trims the cold set
        s.scheduler.run_until(s.network.clock + ev.scan_period_s + 0.5)
    s.scheduler.quiesce()
    fills = cache_fill_totals(clients)
    home_fills = fills.get("home", 0)
    rep_fills = fills.get("r1", 0)
    capture = rep_fills / max(home_fills + rep_fills, 1)
    peak_frac = rep.peak_resident_bytes / capacity
    report = fab.maintenance_report()
    return (capture, peak_frac, report.evictions,
            s.replicas.admission_refused, rep.resident_bytes <= capacity)


# ---- scenario B: evict and repair contend one LockTable ---------------------

def _evict_repair_contention(root: str, size: int):
    """The evictor's per-path lease blocks a repair of the same path.

    A trimmed path is rewritten while home<->replica is partitioned, so
    it becomes a repair target while the evictor still holds the lease.
    The repair tick must lose the lock (counted), never double-repair,
    and converge once the lease expires.
    """
    from repro.core import (EvictionSpec, MaintenanceSpec, ReplicaPolicy)

    ev = EvictionSpec(capacity=4 * size, high_watermark=0.5,
                      low_watermark=0.25, scan_period_s=5.0)
    spec = MaintenanceSpec(resync_period_s=1e6, lease_period_s=1e6,
                           reconcile_period_s=1e6, repair_period_s=2.0,
                           lock_lease_s=30.0)
    fab = _armed_fabric(f"{root}/home-ct", f"{root}/site-ct",
                        replica_latencies={"r1": 0.005}, maintenance=spec)
    s = fab.login("owner", replicas=ReplicaPolicy(sites=("r1",),
                                                  eviction=ev))
    net, sched = s.network, s.scheduler
    victim = "home/data/v0.bin"
    paths = [victim] + [f"home/data/v{i}.bin" for i in range(1, 3)]
    for p in paths:
        with s.client.open(p, "w") as f:
            f.write(b"V" * size)
        s.client.pump()
        with s.client.open(p) as f:          # touch: later paths are hotter
            f.read()
    rep = s.replicas.replicas["r1"]
    # 3*size resident > high (2*size): the next scan evicts the LRU
    # victim and HOLDS its per-path lease for lock_lease_s
    sched.run_until(net.clock + ev.scan_period_s + 0.1)
    evicted_at = net.clock
    assert victim not in rep.resident
    # rewrite the victim behind a partition: it becomes a repair target
    net.partition("home", "r1")
    with s.client.open(victim, "w") as f:
        f.write(b"W" * size)
    s.client.pump()
    net.heal("home", "r1")
    assert victim in rep.lagging
    # repair ticks run while the evictor's lease is live -> conflicts;
    # after expiry the repair lands
    sched.run_until(evicted_at + 40.0)
    sched.quiesce()
    report = fab.maintenance_report()
    converged = victim not in rep.lagging \
        and rep.store.get(rep.token, victim)[0] == b"W" * size
    return report, converged


# ---- scenario C: quorum-parked bytes survive any trim -----------------------

def _parked_never_evicted(root: str, size: int):
    """Majority writes around a dead home park at the replicas; the
    evict scan, far over its watermark, must leave every parked path."""
    from repro.core import (EvictionSpec, MaintenanceSpec, ReplicaPolicy)

    ev = EvictionSpec(capacity=6 * size, high_watermark=0.5,
                      low_watermark=0.2, scan_period_s=5.0)
    spec = MaintenanceSpec(resync_period_s=1e6, repair_period_s=1e6,
                           lease_period_s=1e6, reconcile_period_s=1e6)
    fab = _armed_fabric(f"{root}/home-qp", f"{root}/site-qp",
                        replica_latencies={"r1": 0.005, "r2": 0.015},
                        maintenance=spec)
    s = fab.login("owner", replicas=ReplicaPolicy(
        sites=("r1", "r2"), write_quorum="majority", eviction=ev))
    # cold filler traffic the trim may reclaim freely
    for i in range(3):
        with s.client.open(f"home/data/cold{i}.bin", "w") as f:
            f.write(b"C" * size)
        s.client.pump()
    net, sched = s.network, s.scheduler
    # home dies; majority writes park at r1+r2 (the only durable copies)
    for ep in ("site", "r1", "r2"):
        net.partition(ep, "home")
    parked = [f"home/data/parked{i}.bin" for i in range(3)]
    for p in parked:
        with s.client.open(p, "w") as f:
            f.write(b"P" * size)
        s.client.pump()
    rep = s.replicas.replicas["r1"]
    over = rep.resident_bytes > ev.high_bytes
    sched.run_until(net.clock + ev.scan_period_s + 0.5)
    sched.quiesce()
    report = fab.maintenance_report()
    parked_evicted = sum(1 for p in parked if p not in rep.resident)
    floor_holds = all(
        s.replicas.catalog.freshness_floor(p) is not None for p in parked)
    return (over, parked_evicted, report.evictions, floor_holds)


# ---- scenario D: unset => bit-identical; alias == spec ----------------------

def _trace_witness(root: str, size: int):
    import warnings

    from repro.core import EvictionSpec, ReplicaPolicy

    def drive(fab, policy, tick=False):
        s = fab.login("bench", replicas=policy)
        path = "home/data/t.bin"
        with s.client.open(path, "w") as f:
            f.write(b"T" * size)
        s.client.pump()
        with s.client.open(path) as f:
            f.read()
        if tick and s.scheduler is not None:
            s.scheduler.run_until(s.network.clock + 12.0)
            s.scheduler.quiesce()
        return s.network.trace

    # eviction unset: the new accounting / LRU-clock / admission code is
    # all wire-free, so a maintenance-armed-but-unticked fabric must
    # still trace bit-identically to no maintenance plane at all (the
    # PR 6 zero-cost gate, extended through the eviction code paths)
    unbounded = ReplicaPolicy(sites=("r1",))
    plain = drive(star_fabric(f"{root}/home-tp", f"{root}/site-tp",
                              latency_s=HOME_LATENCY,
                              replica_latencies={"r1": 0.005}), unbounded)
    armed = drive(_armed_fabric(f"{root}/home-ta", f"{root}/site-ta",
                                replica_latencies={"r1": 0.005}),
                  unbounded)
    unset_same = plain == armed
    # the deprecated alias must wire bit-identically to the explicit
    # spec under identical ticking (capacity far above the working set:
    # the spec is armed but never trims)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        alias_pol = ReplicaPolicy(sites=("r1",),
                                  capacity_bytes=64 * size)
    spec_pol = ReplicaPolicy(sites=("r1",),
                             eviction=EvictionSpec(capacity=64 * size))
    alias = drive(_armed_fabric(f"{root}/home-al", f"{root}/site-al",
                                replica_latencies={"r1": 0.005}),
                  alias_pol, tick=True)
    explicit = drive(_armed_fabric(f"{root}/home-ex", f"{root}/site-ex",
                                   replica_latencies={"r1": 0.005}),
                     spec_pol, tick=True)
    return unset_same, alias == explicit


def run(smoke: bool = False) -> int:
    from repro.core import KB

    # 40 files keeps capacity (10%) = 4 files >= the 3-file hot set in
    # both modes; smoke shrinks bytes and reader count, not the shape
    n_files = 40
    size = 16 * KB if smoke else 64 * KB
    readers = 3 if smoke else 6
    root = tempfile.mkdtemp(prefix="fig_eviction_")
    failures = []
    try:
        # ---- A: capacity census ------------------------------------------
        us, (capture, peak_frac, evictions, refusals, within) = timed(
            lambda: _capacity_census(root, n_files, size, readers))
        emit("eviction/replica_capture_frac", us, f"{capture:.2f}")
        emit("eviction/peak_resident_frac", 0.0, f"{peak_frac:.2f}")
        emit("eviction/scheduled_evictions", 0.0, evictions)
        emit("eviction/admission_refusals", 0.0, refusals)
        if capture <= 0.5:
            failures.append(
                f"10%-capacity replica captured only {capture:.0%} of "
                "fills (must be the majority)")
        if peak_frac > 1.0 or not within:
            failures.append(
                f"replica resident bytes exceeded capacity "
                f"(peak {peak_frac:.2f}x)")
        if evictions <= 0:
            failures.append("the scheduled evict task never evicted "
                            "across the phase rotation")

        # ---- B: evict/repair lock contention -----------------------------
        us, (report, converged) = timed(
            lambda: _evict_repair_contention(root, size))
        emit("eviction/lock_conflicts", us, report.lock_conflicts)
        emit("eviction/double_repairs", 0.0, report.double_repairs)
        if report.lock_conflicts <= 0:
            failures.append("evictor's lease never contended with the "
                            "repair task on the shared LockTable")
        if report.double_repairs != 0:
            failures.append(f"{report.double_repairs} double repair(s) "
                            "with eviction in the mix")
        if not converged:
            failures.append("rewritten-after-evict path did not converge "
                            "once the evictor's lease expired")

        # ---- C: quorum-parked protection ---------------------------------
        us, (over, parked_evicted, evictions_c, floor_holds) = timed(
            lambda: _parked_never_evicted(root, size))
        emit("eviction/parked_evicted", us, parked_evicted)
        emit("eviction/parked_scan_evictions", 0.0, evictions_c)
        if not over:
            failures.append("scenario C never crossed the high watermark "
                            "(trim pressure missing)")
        if parked_evicted != 0:
            failures.append(f"{parked_evicted} quorum-parked path(s) "
                            "evicted — the only durable copies")
        if not floor_holds:
            failures.append("freshness floor lost on a parked path")

        # ---- D: zero-cost + alias equivalence ----------------------------
        us, (unset_same, alias_same) = timed(
            lambda: _trace_witness(root, size))
        emit("eviction/unset_trace_identical", us, int(unset_same))
        emit("eviction/alias_trace_identical", 0.0, int(alias_same))
        if not unset_same:
            failures.append("EvictionSpec unset changed the transport "
                            "trace (zero-cost guarantee broken)")
        if not alias_same:
            failures.append("capacity_bytes alias and explicit "
                            "EvictionSpec wired different traces")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)   # keep stdout valid CSV
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("eviction: OK (10% replica captures the majority; evict "
              "and repair share one LockTable with zero double repairs; "
              "quorum-parked bytes survive any trim; unset => traces "
              "bit-identical)")
    raise SystemExit(rc)
