"""Concurrent-writer safety: divergence, leases, and chaos — self-gated.

Two sessions (``login`` + ``attach``) share one home and one replica set
and write the *same* path while a :class:`FaultPlan` cuts the home
links.  Four scenarios on the virtual WAN clock:

  A. **Divergent branches.**  A declared home outage strands sci on the
     quorum path while bob writes straight at home: two vector-timestamp
     branches that know nothing of each other.  Gate: reconcile detects
     exactly one conflict, deterministic LWW picks sci, the losing
     branch survives verbatim in the ConflictRecord (zero silent
     clobbers), and anti-entropy converges the replicas on the winner.
  B. **Lease serialization.**  Both writers lose home; with
     ``WriteLeaseSpec`` armed the first pump takes the per-path lease on
     the replica set and the second *defers* instead of diverging.
     Gate: ``lease_contended > 0``, zero conflicts, the late writer
     lands causally on top (merged frontier), no lease left dangling.
  C. **Flapping chaos.**  Interleaved FlapEvents on both home links
     while the writers keep writing.  Gate: after the windows lapse and
     both sides drain + reconcile, nothing is pending or parked, home
     holds a written payload, every detected conflict preserves both
     branches, replicas converge — and the whole run is deterministic
     (two universes, bit-identical traces).
  D. **Zero-cost witnesses.**  Arming an *empty* FaultPlan, or
     configuring ``write_lease`` on a writer that never leaves the
     connected path, must leave the transport trace bit-identical to a
     fabric without them.

Rows (modeled virtual-WAN quantities):

  conflict/divergent_conflicts      scenario A (== 1)
  conflict/divergent_winner         scenario A LWW pick ("ours" = sci)
  conflict/branches_preserved       scenario A (1 = no silent clobber)
  conflict/replicas_converged       scenario A post-resync
  conflict/lease_contended          scenario B (> 0)
  conflict/lease_conflicts          scenario B (== 0)
  conflict/merged_frontier          scenario B causal order on top
  conflict/flap_conflicts           scenario C detected divergences
  conflict/flap_acked_lost          scenario C (== 0)
  conflict/flap_drained             scenario C (1 = no parked leftovers)
  conflict/flap_rerun_identical     scenario C determinism witness
  conflict/trace_unarmed_identical  scenario D empty-plan witness
  conflict/trace_lease_unset_identical scenario D connected-path witness
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, star_fabric, timed

HOME_LATENCY = 0.060
PATH = "home/shared/doc.bin"


def _two_writer_fab(root: str, tag: str, write_lease=None):
    from repro.core import MountSpec, ReplicaPolicy, SiteSpec

    fab = star_fabric(f"{root}/home-{tag}", f"{root}/site-{tag}",
                      latency_s=HOME_LATENCY,
                      replica_latencies={"r1": 0.005, "r2": 0.015},
                      extra_sites=(SiteSpec(
                          "site2", root=f"{root}/site2-{tag}"),))
    s = fab.login("sci", replicas=ReplicaPolicy(
        sites=("r1", "r2"), write_quorum="majority",
        write_lease=write_lease))
    bob = fab.attach(s, "site2", owner="bob", mounts=[MountSpec("home/")])
    return fab, s, bob


# ---- scenario A: divergent branches under a declared outage -----------------

def _divergent(root: str, size: int):
    from repro.core import FaultPlan, PartitionEvent

    fab, s, bob = _two_writer_fab(root, "a")
    net = s.network
    t0 = net.clock
    fab.arm_faults(FaultPlan(events=(
        PartitionEvent(at_s=t0, a="site", b="home", duration_s=30.0),)))
    sci_bytes, bob_bytes = b"S" * size, b"B" * (size - 1024)
    with s.client.open(PATH, "w") as f:
        f.write(sci_bytes)
    s.client.pump()                        # parks at quorum (r1 + r2)
    with bob.open(PATH, "w") as f:
        f.write(bob_bytes)
    bob.pump()                             # lands at home, vts {bob:1}
    net.advance(t0 + 30.0 - net.clock)     # outage window lapses
    reconciled = s.client.reconcile()
    conflicts = list(s.client.conflicts)
    s.replicas.resync()
    home = s.server.store.get(s.token, PATH)[0]
    converged = all(rep.store.get(rep.token, PATH)[0] == home
                    for rep in s.replicas.replicas.values())
    preserved = (len(conflicts) == 1
                 and conflicts[0].ours_data == sci_bytes
                 and conflicts[0].theirs_data == bob_bytes)
    return {
        "reconciled": reconciled,
        "conflicts": conflicts,
        "winner": conflicts[0].winner if conflicts else "none",
        "home_is_sci": home == sci_bytes,
        "frontier": s.server.store.vts_of(PATH),
        "preserved": preserved,
        "converged": converged,
        "parked": len(s.client.oplog.unreconciled()),
    }


# ---- scenario B: leases serialize two stranded quorum writers ---------------

def _lease_serialized(root: str, size: int):
    from repro.core import FaultPlan, PartitionEvent, WriteLeaseSpec

    fab, s, bob = _two_writer_fab(root, "b",
                                  write_lease=WriteLeaseSpec(ttl_s=60.0))
    net = s.network
    t0 = net.clock
    fab.arm_faults(FaultPlan(events=(
        PartitionEvent(at_s=t0, a="site", b="home", duration_s=30.0),
        PartitionEvent(at_s=t0, a="site2", b="home", duration_s=30.0),)))
    with s.client.open(PATH, "w") as f:
        f.write(b"S" * size)
    s.client.pump()                        # sci parks, holds the lease
    with bob.open(PATH, "w") as f:
        f.write(b"B" * size)
    bob.pump()                             # contended: bob defers, queued
    bob_deferred = len(bob.oplog.pending())
    net.advance(t0 + 30.0 - net.clock)     # both windows lapse
    s.client.reconcile()                   # sci lands; lease released
    bob.pump()                             # bob retries, lands ON TOP
    home = s.server.store.get(s.token, PATH)[0]
    dangling = sum(1 for rep in s.replicas.replicas.values()
                   if rep.store.lock_owner(PATH, net.clock) is not None)
    return {
        "contended": s.replicas.lease_contended,
        "acquired": s.replicas.lease_acquired,
        "bob_deferred": bob_deferred,
        "conflicts": len(s.client.conflicts) + len(bob.conflicts),
        "home_is_bob": home == b"B" * size,
        "frontier": s.server.store.vts_of(PATH),
        "dangling": dangling,
    }


# ---- scenario C: flapping chaos, drain, converge, determinism ---------------

def _flap_chaos(root: str, tag: str, size: int, rounds: int):
    from repro.core import FaultPlan, FlapEvent

    fab, s, bob = _two_writer_fab(root, tag)
    net = s.network
    t0 = net.clock
    flaps = max(1, rounds // 2)
    fab.arm_faults(FaultPlan(events=(
        FlapEvent(at_s=t0 + 1.0, a="site", b="home", down_s=6.0,
                  period_s=16.0, count=flaps),
        FlapEvent(at_s=t0 + 9.0, a="site2", b="home", down_s=6.0,
                  period_s=16.0, count=flaps),)))
    writers = ((s.client, "sci"), (bob, "bob"))
    payloads = set()
    for rnd in range(rounds):
        for client, owner in writers:
            data = f"{owner}:{rnd}:".encode() * max(1, size // 8)
            payloads.add(data)
            with client.open(PATH, "w") as f:
                f.write(data)
            client.pump()
        net.advance(8.0)
        for client, _ in writers:
            client.pump()
            client.reconcile()
    net.advance(max(0.0, (t0 + 1.0 + flaps * 16.0) - net.clock) + 10.0)
    for _ in range(3):
        for client, _ in writers:
            client.pump()
            client.reconcile()
    s.replicas.resync()
    home = s.server.store.get(s.token, PATH)[0]
    conflicts = list(s.client.conflicts) + list(bob.conflicts)
    drained = not any(c.oplog.pending() or c.oplog.unreconciled()
                      for c, _ in writers)
    converged = all(rep.store.get(rep.token, PATH)[0] == home
                    for rep in s.replicas.replicas.values())
    acked_lost = 0 if (home in payloads and all(
        c.ours_data in payloads and c.theirs_data in payloads
        for c in conflicts)) else 1
    return {
        "conflicts": len(conflicts),
        "acked_lost": acked_lost,
        "drained": drained,
        "converged": converged,
        "trace": tuple(net.trace),
    }


# ---- scenario D: zero-cost witnesses ----------------------------------------

def _drive_quorum(fab, size: int, write_lease=None):
    from repro.core import ReplicaPolicy

    s = fab.login("bench", replicas=ReplicaPolicy(
        sites=("r1", "r2"), write_quorum="majority",
        write_lease=write_lease))
    with s.client.open("home/d/t.bin", "w") as f:
        f.write(b"T" * size)
    s.client.pump()                        # connected: straight to home
    with s.client.open("home/d/t.bin") as f:
        f.read()
    return s.network.trace


def _trace_witnesses(root: str, size: int):
    from repro.core import FaultPlan, WriteLeaseSpec

    def fresh(tag):
        return star_fabric(f"{root}/home-{tag}", f"{root}/site-{tag}",
                           latency_s=HOME_LATENCY,
                           replica_latencies={"r1": 0.005, "r2": 0.015})

    plain = _drive_quorum(fresh("d0"), size)
    armed_fab = fresh("d1")
    armed_fab.arm_faults(FaultPlan())      # armed but empty
    armed = _drive_quorum(armed_fab, size)
    leased = _drive_quorum(fresh("d2"), size,
                           write_lease=WriteLeaseSpec(ttl_s=10.0))
    return plain == armed, plain == leased


def run(smoke: bool = False) -> int:
    from repro.core import KB

    size = 64 * KB if smoke else 512 * KB
    rounds = 6 if smoke else 12
    root = tempfile.mkdtemp(prefix="fig_conflict_")
    failures = []
    try:
        # ---- A: divergent branches ---------------------------------------
        us, a = timed(lambda: _divergent(root, size))
        emit("conflict/divergent_conflicts", us, len(a["conflicts"]))
        emit("conflict/divergent_winner", 0.0, a["winner"])
        emit("conflict/branches_preserved", 0.0, int(a["preserved"]))
        emit("conflict/replicas_converged", 0.0, int(a["converged"]))
        if len(a["conflicts"]) != 1:
            failures.append(f"divergent write produced {len(a['conflicts'])}"
                            " conflict(s), expected exactly 1")
        if a["winner"] != "ours" or not a["home_is_sci"]:
            failures.append("deterministic LWW did not land sci's branch "
                            f"(winner={a['winner']})")
        if not a["preserved"]:
            failures.append("losing branch not preserved verbatim in the "
                            "ConflictRecord (silent clobber)")
        if a["frontier"] != {"sci": 1, "bob": 1}:
            failures.append(f"merged frontier {a['frontier']} does not "
                            "cover both branches")
        if not a["converged"] or a["parked"]:
            failures.append("replicas did not converge on the resolved "
                            "branch after resync")

        # ---- B: lease serialization --------------------------------------
        us, b = timed(lambda: _lease_serialized(root, size))
        emit("conflict/lease_contended", us, b["contended"])
        emit("conflict/lease_conflicts", 0.0, b["conflicts"])
        emit("conflict/merged_frontier", 0.0,
             ";".join(f"{k}:{v}" for k, v in sorted(b["frontier"].items())))
        if b["contended"] <= 0 or b["bob_deferred"] != 1:
            failures.append("second quorum writer never contended the "
                            "write lease (serialization broken)")
        if b["conflicts"] != 0:
            failures.append(f"{b['conflicts']} conflict(s) under lease "
                            "serialization, expected 0")
        if not b["home_is_bob"] or b["frontier"] != {"sci": 1, "bob": 1}:
            failures.append("deferred writer did not land causally on top "
                            "of the lease holder")
        if b["dangling"]:
            failures.append(f"{b['dangling']} replica lease(s) left "
                            "dangling after the writers drained")

        # ---- C: flapping chaos + determinism -----------------------------
        us, c1 = timed(lambda: _flap_chaos(root, "c1", size, rounds))
        c2 = _flap_chaos(root, "c2", size, rounds)
        emit("conflict/flap_conflicts", us, c1["conflicts"])
        emit("conflict/flap_acked_lost", 0.0, c1["acked_lost"])
        emit("conflict/flap_drained", 0.0, int(c1["drained"]))
        emit("conflict/flap_rerun_identical", 0.0,
             int(c1["trace"] == c2["trace"]))
        if c1["acked_lost"]:
            failures.append("flap chaos lost an acknowledged write (home "
                            "bytes or a conflict branch escaped the "
                            "written set)")
        if not c1["drained"]:
            failures.append("writers still have pending/parked records "
                            "after the flap windows lapsed")
        if not c1["converged"]:
            failures.append("replicas diverged from home after flap chaos")
        if c1["trace"] != c2["trace"]:
            failures.append("flap chaos is not deterministic: identical "
                            "universes produced different traces")

        # ---- D: zero-cost witnesses --------------------------------------
        us, (armed_same, lease_same) = timed(
            lambda: _trace_witnesses(root, size))
        emit("conflict/trace_unarmed_identical", us, int(armed_same))
        emit("conflict/trace_lease_unset_identical", 0.0, int(lease_same))
        if not armed_same:
            failures.append("arming an empty FaultPlan changed the "
                            "transport trace (zero-cost guarantee broken)")
        if not lease_same:
            failures.append("write_lease config changed the connected-path "
                            "trace (lease must cost zero wire off the "
                            "quorum path)")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)   # keep stdout valid CSV
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("conflict: OK (divergent branches => one ConflictRecord, LWW "
              "deterministic, loser preserved; leases serialize stranded "
              "writers with zero conflicts; flap chaos drains, converges, "
              "deterministic; unarmed machinery trace-identical)")
    raise SystemExit(rc)
