"""Hotspot/incast: N clients pull one hot object from a 2-replica fabric.

Every client is nearest to replica ``r1`` (5 ms), then ``r2`` (15 ms),
with home a 60 ms WAN hop away; each server endpoint carries a NIC
budget.  Under static nearest-by-latency routing every client piles onto
``r1`` and its NIC backlog serializes the incast.  Queue-aware routing
prices each candidate by estimated completion (latency + channel queue
+ NIC backlog), so later clients shed to ``r2`` and ultimately home,
draining the same byte volume across three uplinks.

Rows (modeled virtual-WAN quantities):

  congestion/incast_static_drain_s      budgets on, latency-ranked routing
  congestion/incast_aware_drain_s       budgets on, estimated-completion
                                        routing (must be strictly lower)
  congestion/endpoint_tput_frac_<ep>    measured bytes/s over the drain
                                        divided by the NIC budget (<= 1)
  congestion/budgets_off_trace_identical 1 when, with budgets disabled,
                                        queue-aware and static runs issue
                                        bit-identical transport traces
                                        (the PR 3 equivalence witness)
  congestion/util_<ep>                  per-endpoint busy-seconds /
                                        busy-fraction / bytes

Run standalone (and from ``run.py`` / CI ``--smoke``), exits non-zero
unless: queue-aware drain strictly beats static drain under the incast;
no endpoint's measured throughput exceeds its NIC budget; and with
budgets disabled the queue-aware trace is bit-identical to the static
trace (routing unchanged on an idle-per-pair network — the PR 3
benchmark numbers cannot move).
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (
    emit, emit_endpoint_utilization, star_fabric, timed,
)

HOME_LATENCY = 0.060
REPLICA_SITES = {"r1": 0.005, "r2": 0.015}
SERVERS = ("home", "r1", "r2")
HOT_PATH = "home/hot/model.bin"


def _build(root: str, tag: str, n_clients: int, size: int,
           budget, queue_aware: bool):
    """One incast universe: home + 2 replicas + N client endpoints, all
    declared up front in one spec."""
    from repro.core import LinkSpec, ReplicaPolicy, SiteSpec

    clients = [f"c{i}" for i in range(n_clients)]
    fab = star_fabric(
        f"{root}/home-{tag}", f"{root}/site-{tag}",
        latency_s=HOME_LATENCY, replica_latencies=REPLICA_SITES,
        extra_sites=tuple(SiteSpec(c) for c in clients),
        extra_links=tuple(LinkSpec(c, rname, latency_s=lat)
                          for c in clients
                          for rname, lat in REPLICA_SITES.items()))
    s = fab.login("bench",
                  replicas=ReplicaPolicy(sites=tuple(REPLICA_SITES),
                                         queue_aware=queue_aware))
    s.server.store.put(s.token, HOT_PATH, b"H" * size)
    s.replicas.resync()
    net = fab.network
    if budget is not None:
        # budgets arm AFTER the seed resync: the incast measures steady
        # state, not a replica fill charged against the cap
        for ep in SERVERS:
            net.set_nic_budget(ep, budget)
    return s, clients


def _incast(s, clients, size: int):
    """Each client routes the hot object and begins a striped pull; the
    drain time is the overlapped completion of the whole incast."""
    from repro.core import StripedTransfer

    net = s.client.network
    xfer = StripedTransfer(net)
    t0 = net.clock
    bytes0 = dict(net.per_endpoint_bytes)
    sources = []
    for cname in clients:
        for name, store, token in s.replicas.route(cname, HOT_PATH,
                                                   nbytes=size):
            if net.is_partitioned(cname, name):
                continue
            data, _st = store.get(token, HOT_PATH)
            xfer.begin(name, cname, data)
            sources.append(name)
            break
    net.drain()
    return net.clock - t0, bytes0, sources


def run(smoke: bool = False) -> int:
    from repro.core import MB

    n_clients = 6 if smoke else 12
    size = 1 * MB if smoke else 4 * MB
    budget = (50 * MB) if smoke else (100 * MB)
    root = tempfile.mkdtemp(prefix="fig_congestion_")
    failures = []
    try:
        # ---- budgets ON: static vs queue-aware routing -------------------
        drains = {}
        for mode, aware in (("static", False), ("aware", True)):
            s, clients = _build(root, f"on-{mode}", n_clients, size,
                                budget, queue_aware=aware)
            us, (drain_s, bytes0, sources) = timed(
                lambda s=s, clients=clients: _incast(s, clients, size))
            drains[mode] = drain_s
            emit(f"congestion/incast_{mode}_drain_s", us, f"{drain_s:.4f}")
            spread = {ep: sources.count(ep) for ep in SERVERS
                      if ep in sources}
            emit(f"congestion/incast_{mode}_source_spread", 0.0,
                 ";".join(f"{ep}={n}" for ep, n in sorted(spread.items())))
            # measured per-endpoint throughput must respect the budget
            net = s.client.network
            for ep in SERVERS:
                moved = net.per_endpoint_bytes.get(ep, 0) \
                    - bytes0.get(ep, 0)
                frac = (moved / drain_s) / budget if drain_s > 0 else 0.0
                if mode == "aware":
                    emit(f"congestion/endpoint_tput_frac_{ep}", 0.0,
                         f"{frac:.3f}")
                if frac > 1.0 + 1e-9:
                    failures.append(
                        f"{mode}: endpoint {ep} moved {moved} B in "
                        f"{drain_s:.4f}s = {frac:.2f}x its NIC budget")
            if mode == "aware":
                emit_endpoint_utilization("congestion", net,
                                          endpoints=list(SERVERS))
            if mode == "static" and len(set(sources)) != 1:
                failures.append(
                    f"static routing did not incast onto one replica: "
                    f"{spread}")
            if mode == "aware" and len(set(sources)) < 2:
                failures.append(
                    f"queue-aware routing never shed the hot replica: "
                    f"{spread}")
        if not drains["aware"] < drains["static"]:
            failures.append(
                f"queue-aware drain ({drains['aware']:.4f}s) not strictly "
                f"faster than static ({drains['static']:.4f}s)")

        # ---- budgets OFF: PR 3 equivalence -------------------------------
        # With no NIC budgets, every client pair is idle at route time, so
        # estimated completion degenerates to static latency ordering: the
        # two modes must issue bit-identical transport traces.
        traces = {}
        for mode, aware in (("static", False), ("aware", True)):
            s, clients = _build(root, f"off-{mode}", n_clients, size,
                                None, queue_aware=aware)
            _incast(s, clients, size)
            traces[mode] = s.client.network.trace
        same = traces["aware"] == traces["static"]
        emit("congestion/budgets_off_trace_identical", 0.0, int(same))
        if not same:
            failures.append(
                "budgets disabled: queue-aware trace diverged from the "
                "static-latency trace (PR 3 behavior changed)")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)   # keep stdout valid CSV
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("congestion: OK (queue-aware routing drains the incast "
              "strictly faster; NIC budgets never exceeded; budgets off "
              "=> PR 3 traces bit-identical)")
    raise SystemExit(rc)
