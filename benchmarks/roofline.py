"""Beyond-paper: roofline table from the dry-run artifacts.

Reads experiments/artifacts/*.json (produced by ``python -m
repro.launch.dryrun --all --mesh both``) and emits one row per cell:
``derived`` = dominant-term milliseconds; plus the compute-roofline
fraction.  EXPERIMENTS.md §Roofline is generated from the same data.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "artifacts")


def load_artifacts():
    arts = []
    for path in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(path) as f:
            arts.append(json.load(f))
    return arts


def run(smoke: bool = False) -> None:
    # smoke-compatible as-is: reads precomputed artifacts, no heavy work
    arts = load_artifacts()
    if not arts:
        emit("roofline/no_artifacts_found", 0.0, 0)
        return
    n_ok = 0
    worst = None
    for a in arts:
        r = a["roofline"]
        variant = "opt" if a.get("tag") == "opt" else "base"
        if a.get("tag") not in ("", None, "opt"):
            continue
        cell = f"{a['arch']}/{a['shape']}/{a['mesh']}/{variant}"
        emit(f"roofline/{cell}/dominant_{r['dominant']}_ms", 0.0,
             round(r["step_lower_bound_s"] * 1e3, 2))
        emit(f"roofline/{cell}/compute_fraction", 0.0,
             round(r["roofline_fraction_compute"], 4))
        if variant == "base":
            n_ok += 1
            frac = r["roofline_fraction_compute"]
            if worst is None or frac < worst[1]:
                worst = (cell, frac)
    emit("roofline/cells_compiled", 0.0, n_ok)
    if worst:
        emit("roofline/worst_cell", 0.0, f"{worst[0]}@{worst[1]:.3f}")
