"""Maintenance plane: scheduled background upkeep vs inline-on-the-reader.

Four self-gating scenarios on the virtual WAN clock:

  A. **Tail latency.**  A home-side producer keeps rewriting K objects
     while a site reader polls them; the home<->replica link flaps
     (partition + auto-heal), so anti-entropy keeps finding work.
     ``inline`` runs the pre-maintenance idiom — resync/renewal ride the
     reader's critical path each round; ``scheduled`` runs the identical
     cadence inside think time via ``MaintenanceScheduler.run_until``.
     Gate: scheduled read p99 strictly below inline read p99.
  B. **Dead-letter lifecycle.**  A permanent site<->home partition fails
     the scheduled resync probe; the task must retry on the 1s/2s/4s
     ladder and land in the dead-letter record (attempts=4, backoff
     history verbatim), then ``revive()`` after the heal must converge
     the replica again.
  C. **Never double-repair.**  Two sessions (login + attach) share one
     replica set with a far replica; both repair tasks see the same
     lagging paths while the first session's repair acks are still in
     flight.  Gate: ``lock_conflicts > 0`` and ``double_repairs == 0``,
     and the replica converges.
  D. **Zero-cost guarantee.**  With ``MaintenanceSpec`` unset — and with
     it set but never ticked — the transport trace must be bit-identical
     to the pre-maintenance fabric.

Rows (modeled virtual-WAN quantities):

  maintenance/inline_read_p99_s         scenario A, inline upkeep
  maintenance/scheduled_read_p99_s      scenario A, scheduled upkeep
  maintenance/deadletter_attempts       scenario B (initial + retries)
  maintenance/deadletter_backoff_s      scenario B ladder, verbatim
  maintenance/revive_converged          scenario B, post-heal recovery
  maintenance/lock_conflicts            scenario C (> 0)
  maintenance/double_repairs            scenario C (== 0)
  maintenance/spec_unset_trace_identical scenario D
"""
from __future__ import annotations

import os
import shutil
import sys
import tempfile

if __name__ == "__main__":
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit, star_fabric, timed

HOME_LATENCY = 0.060
THINK_S = 5.0


def _p99(samples):
    xs = sorted(samples)
    return xs[min(len(xs) - 1, max(0, round(0.99 * len(xs)) - 1))]


def _maintained_fabric(home_root, site_root, *, replica_latencies,
                       extra_sites=(), maintenance=None):
    import dataclasses

    from repro.core import Fabric, MaintenanceSpec

    fab = star_fabric(home_root, site_root, latency_s=HOME_LATENCY,
                      replica_latencies=replica_latencies,
                      extra_sites=extra_sites)
    spec = dataclasses.replace(fab.spec,
                               maintenance=maintenance or MaintenanceSpec())
    return Fabric(spec)


# ---- scenario A: inline vs scheduled tail latency ---------------------------

def _tail_latency(root: str, mode: str, rounds: int, size: int):
    """One producer/reader universe; returns per-round read latencies.

    ``inline``: anti-entropy + lease renewal run synchronously on the
    reader's clock right before each read (the pre-maintenance idiom).
    ``scheduled``: the same upkeep cadence rides think time through the
    scheduler; the read pays only its own fill.
    """
    from repro.core import (
        FaultPlan, FlapEvent, MaintenanceSpec, ReplicaPolicy,
    )

    n_files = 4
    spec = MaintenanceSpec(resync_period_s=THINK_S,
                           repair_period_s=THINK_S,
                           lease_period_s=2 * THINK_S,
                           reconcile_period_s=2 * THINK_S)
    if mode == "scheduled":
        fab = _maintained_fabric(f"{root}/home-{mode}", f"{root}/site-{mode}",
                                 replica_latencies={"r1": 0.005},
                                 maintenance=spec)
    else:
        fab = star_fabric(f"{root}/home-{mode}", f"{root}/site-{mode}",
                          latency_s=HOME_LATENCY,
                          replica_latencies={"r1": 0.005})
    s = fab.login("bench", replicas=ReplicaPolicy(sites=("r1",)))
    paths = [f"home/data/f{i}.bin" for i in range(n_files)]
    for p in paths:
        s.server.store.put(s.token, p, b"S" * size)
    s.replicas.resync()
    net = s.network
    # the WAN flaps on a declared cadence (was a hand-rolled
    # ``net.partition(...)`` every 8th round): ~one 2*THINK_S outage per
    # 8 think windows, so anti-entropy work keeps piling up and healing
    # mid-run in both modes identically
    fab.arm_faults(FaultPlan(events=(
        FlapEvent(at_s=net.clock + 3 * THINK_S, a="home", b="r1",
                  down_s=2 * THINK_S, period_s=8 * THINK_S,
                  count=max(1, rounds // 8)),)))
    lats = []
    for i in range(rounds):
        # producer rewrites one object at home: the replica goes stale
        s.server.store.put(s.token, paths[i % n_files],
                           bytes([65 + i % 26]) * size)
        # think time: scheduled mode hosts the upkeep here; inline mode
        # just idles — its upkeep fires on the next read, below
        if mode == "scheduled":
            s.scheduler.run_until(net.clock + THINK_S)
        else:
            net.advance(THINK_S)
        t0 = net.clock
        if mode == "inline":
            # pre-maintenance idiom: the read request that finds upkeep
            # due performs it first — anti-entropy, lease renewal, and
            # reconciliation all ride the reader's critical path
            s.replicas.resync()
            for lm in s.client.leases.values():
                lm.renew_all()
            s.client.reconcile()
        with s.client.open(paths[(i * 3 + 1) % n_files]) as f:
            f.read()
        lats.append(net.clock - t0)
    if mode == "scheduled":
        s.scheduler.quiesce()
    return lats


# ---- scenario B: dead-letter + revive ---------------------------------------

def _deadletter_lifecycle(root: str):
    from repro.core import FaultPlan, PartitionEvent, ReplicaPolicy

    fab = _maintained_fabric(f"{root}/home-dl", f"{root}/site-dl",
                             replica_latencies={"r1": 0.005})
    s = fab.login("bench", replicas=ReplicaPolicy(sites=("r1",)))
    path = "home/data/x.bin"
    s.server.store.put(s.token, path, b"A" * 65536)
    s.replicas.resync()
    net, sched = s.network, s.scheduler
    t0 = net.clock
    # declared 40 s site<->home outage (was a hand-rolled partition +
    # heal pair); the scheduler pumps the plan as it walks the clock and
    # the window auto-heals exactly at t0+40
    fab.arm_faults(FaultPlan(events=(
        PartitionEvent(at_s=t0, a="site", b="home", duration_s=40.0),)))
    sched.run_until(t0 + 40.0)        # due +30, retries +31/+33/+37, dead
    report = sched.report()
    dls = [d for d in report.dead_letters if d.task.startswith("resync:")]
    dl = dls[0] if dls else None
    # healed by the lapsed window: home writes once more, then the
    # operator revives the task
    s.server.store.put(s.token, path, b"B" * 65536)
    sched.revive("resync:bench@site")
    sched.run_until(net.clock + 31.0)
    sched.quiesce()
    cat = s.replicas.catalog
    hv = s.server.store.stat_unchecked(path).version
    converged = (cat.version_at(path, "r1") == hv
                 and not sched.tasks["resync:bench@site"].dead)
    return dl, converged


# ---- scenario C: two sessions, one replica set, zero double repairs ---------

def _shared_repair(root: str, size: int):
    from repro.core import MountSpec, ReplicaPolicy, SiteSpec

    fab = _maintained_fabric(
        f"{root}/home-sh", f"{root}/site-sh",
        replica_latencies={"r1": 1.0},        # far: repair acks linger
        extra_sites=(SiteSpec("site2", root=f"{root}/site2-sh"),))
    s = fab.login("sci", replicas=ReplicaPolicy(sites=("r1",)))
    fab.attach(s, "site2", owner="bob", mounts=[MountSpec("home/")])
    net = s.network
    paths = [f"home/data/hot{i}.bin" for i in range(3)]
    for p in paths:
        with s.client.open(p, "w") as f:
            f.write(b"H" * size)
    net.partition("home", "r1")
    s.client.pump()                   # home acks; replica fan-out defers
    net.heal("home", "r1")
    lagging = set(s.replicas.replicas["r1"].lagging)
    s.scheduler.run_until(net.clock + 7.0)    # both sessions' repair ticks
    s.scheduler.quiesce()
    report = fab.maintenance_report()
    converged = not s.replicas.replicas["r1"].lagging \
        and lagging == set(paths)
    return report, converged


# ---- scenario D: spec unset => bit-identical traces -------------------------

def _trace_witness(root: str, size: int):
    from repro.core import ReplicaPolicy

    def drive(fab, tag):
        s = fab.login("bench", replicas=ReplicaPolicy(sites=("r1",)))
        path = "home/data/t.bin"
        with s.client.open(path, "w") as f:
            f.write(b"T" * size)
        s.client.pump()
        with s.client.open(path) as f:
            f.read()
        return s.network.trace

    plain = drive(star_fabric(f"{root}/home-tp", f"{root}/site-tp",
                              latency_s=HOME_LATENCY,
                              replica_latencies={"r1": 0.005}), "plain")
    armed = drive(_maintained_fabric(f"{root}/home-ta", f"{root}/site-ta",
                                     replica_latencies={"r1": 0.005}),
                  "armed")
    return plain == armed


def run(smoke: bool = False) -> int:
    from repro.core import KB, MB

    rounds = 24 if smoke else 64
    size = 256 * KB if smoke else 1 * MB
    root = tempfile.mkdtemp(prefix="fig_maintenance_")
    failures = []
    try:
        # ---- A: tail latency ---------------------------------------------
        p99 = {}
        for mode in ("inline", "scheduled"):
            us, lats = timed(lambda m=mode: _tail_latency(root, m, rounds,
                                                          size))
            p99[mode] = _p99(lats)
            emit(f"maintenance/{mode}_read_p99_s", us, f"{p99[mode]:.4f}")
            emit(f"maintenance/{mode}_read_mean_s", 0.0,
                 f"{sum(lats) / len(lats):.4f}")
        if not p99["scheduled"] < p99["inline"]:
            failures.append(
                f"scheduled read p99 ({p99['scheduled']:.4f}s) not "
                f"strictly below inline ({p99['inline']:.4f}s)")

        # ---- B: dead-letter + revive -------------------------------------
        us, (dl, converged) = timed(lambda: _deadletter_lifecycle(root))
        if dl is None:
            failures.append("resync task never dead-lettered under the "
                            "permanent partition")
            emit("maintenance/deadletter_attempts", us, "none")
        else:
            emit("maintenance/deadletter_attempts", us, dl.attempts)
            emit("maintenance/deadletter_backoff_s", 0.0,
                 ";".join(f"{b:g}" for b in dl.backoff_s))
            if dl.attempts < 4:       # initial + >= 3 retries
                failures.append(f"dead letter after only {dl.attempts} "
                                "attempts (ladder must run >= 3 retries)")
            if tuple(dl.backoff_s) != (1.0, 2.0, 4.0):
                failures.append(f"backoff history {dl.backoff_s} is not "
                                "the deterministic 1s/2s/4s ladder")
        emit("maintenance/revive_converged", 0.0, int(converged))
        if not converged:
            failures.append("revived resync task did not re-converge the "
                            "replica after the heal")

        # ---- C: shared repair locks --------------------------------------
        us, (report, converged) = timed(lambda: _shared_repair(root, size))
        emit("maintenance/lock_conflicts", us, report.lock_conflicts)
        emit("maintenance/double_repairs", 0.0, report.double_repairs)
        emit("maintenance/repairs", 0.0, report.repairs)
        if report.lock_conflicts <= 0:
            failures.append("two sessions sharing a replica set never "
                            "contended a repair lock")
        if report.double_repairs != 0:
            failures.append(f"{report.double_repairs} double repair(s): "
                            "per-path locks failed")
        if not converged:
            failures.append("shared replica set did not converge after "
                            "scheduled repairs")

        # ---- D: zero-cost witness ----------------------------------------
        us, same = timed(lambda: _trace_witness(root, size))
        emit("maintenance/spec_unset_trace_identical", us, int(same))
        if not same:
            failures.append("MaintenanceSpec set-but-never-ticked changed "
                            "the transport trace (zero-cost guarantee "
                            "broken)")
    finally:
        shutil.rmtree(root, ignore_errors=True)
    for msg in failures:
        print(f"FAIL: {msg}", file=sys.stderr)   # keep stdout valid CSV
    return 1 if failures else 0


if __name__ == "__main__":
    rc = run(smoke="--smoke" in sys.argv)
    if rc == 0:
        print("maintenance: OK (scheduled upkeep beats inline p99; "
              "dead-letter ladder 1s/2s/4s + revive recovers; shared "
              "repairs conflict-counted, never doubled; spec unset => "
              "traces bit-identical)")
    raise SystemExit(rc)
